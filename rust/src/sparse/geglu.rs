//! Gated activations on column-major buffers (paper §5.2, Table 4).
//!
//! After a 2:4-spMM with the fused Table-12 epilogue
//! ([`crate::sparse::kernels::spmm_nt_cm_into`]) the output Z ∈ R^{p×2r}
//! is COLUMN-major (Appendix A.2). Computing GELU(Z1) ⊙ Z2 by traversing
//! rows ("intuitive") therefore strides by p between consecutive
//! accesses and thrashes the cache; traversing columns ("ours") is
//! contiguous. Both traversal orders are implemented faithfully so the
//! Table-4 bench measures the real cache effect on this substrate.
//!
//! What the FFN substrates actually run:
//! * the sparse paths ([`crate::sparse::ffn::SparseFfn`] /
//!   [`crate::sparse::ffn::FrozenFfn`]) keep Z column-major end to end —
//!   [`geglu_cm_into`] (forward) and [`geglu_cm_grad_into`] (backward)
//!   consume it in place, column order, sharing the same inner loop as
//!   the Table-4 [`geglu_col_order`] kernel. Layout conversion happens
//!   only inside the surrounding spMM epilogues at the block boundary.
//! * the dense baseline ([`crate::sparse::ffn::DenseFfn`]) keeps
//!   row-major activations (its GEMMs are row-major native) and runs
//!   [`geglu_row_major_into`] / [`geglu_row_major_grad_into`].
//!
//! **SIMD forward.** The fused-forward inner loop is vectorized 8-wide
//! ([`geglu_lane`]): GELU's tanh is evaluated by a branch-free
//! range-reduced exp ([`gelu_fast`]) whose scalar and SIMD twins
//! execute the SAME plain-op sequence (no FMA contraction, no libm), so
//! the SIMD body and the scalar tail are bitwise identical per element.
//! That invariant is load-bearing: the column-major and row-major entry
//! points slice the same logical element into lanes of different
//! lengths (p vs r), so it may hit the SIMD body in one layout and the
//! tail in the other — the existing bitwise cross-layout tests only
//! keep passing because the two bodies agree to the last bit.
//! `gelu_fast` stays within 1e-6 (relative for |x| > 1) of the libm
//! [`gelu`], which remains the scalar oracle and the backward's
//! evaluator (the backward pairs `gelu`/`gelu_grad`, both libm, so its
//! own cross-layout bitwise identity is untouched).

use crate::tensor::Tensor;
use std::simd::prelude::*;
use std::simd::StdFloat;

const SQRT_2_OVER_PI: f32 = 0.797_884_56;
const GELU_C: f32 = 0.044_715;

/// tanh-approximated GELU via libm `tanh` — the scalar oracle the fast
/// path ([`gelu_fast`]) is differentially pinned against, and the
/// evaluator the backward kernels use (same constants, same operation
/// order as the forward, so fwd/bwd share one approximation family).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + GELU_C * x * x * x)).tanh())
}

const LANES: usize = 8;
type F8 = Simd<f32, LANES>;

/// tanh saturation cutoff: for t = 2|v| >= 20, 2/(e^t + 1) < 4.2e-9 is
/// under half an ulp of 1.0, so m rounds to exactly 1.0 — matching libm
/// tanh's saturation — while keeping the 2^n exponent trick in range
/// (n <= 29).
const TANH_CLAMP: f32 = 20.0;
const LOG2_E: f32 = std::f32::consts::LOG2_E;
const LN_2: f32 = std::f32::consts::LN_2;
// Taylor coefficients 1/k! for e^w on |w| <= ln(2)/2; degree 7 leaves
// a ~5e-9 relative truncation error, far under the 1e-6 gate
const EXP_C2: f32 = 1.0 / 2.0;
const EXP_C3: f32 = 1.0 / 6.0;
const EXP_C4: f32 = 1.0 / 24.0;
const EXP_C5: f32 = 1.0 / 120.0;
const EXP_C6: f32 = 1.0 / 720.0;
const EXP_C7: f32 = 1.0 / 5040.0;

/// Branch-free tanh: t = min(2|v|, clamp), e^t by range reduction
/// (e^t = 2^n e^w, |w| <= ln(2)/2, degree-7 Horner), then
/// tanh(|v|) = 1 - 2/(e^t + 1), sign restored at the end.
///
/// Every operation is a plain IEEE add/sub/mul/div/min/floor — no
/// libm, no mul_add — so [`tanh_fast_simd`] can replay the identical
/// sequence and produce bitwise-equal results lane for lane.
#[inline]
fn tanh_fast(v: f32) -> f32 {
    let a = v.abs();
    let t = (2.0 * a).min(TANH_CLAMP);
    let u = t * LOG2_E;
    let n = (u + 0.5).floor();
    let w = (u - n) * LN_2;
    let mut e = EXP_C7;
    e = e * w + EXP_C6;
    e = e * w + EXP_C5;
    e = e * w + EXP_C4;
    e = e * w + EXP_C3;
    e = e * w + EXP_C2;
    e = e * w + 1.0;
    e = e * w + 1.0;
    let scale = f32::from_bits((((n as i32) + 127) as u32) << 23);
    let m = 1.0 - 2.0 / (e * scale + 1.0);
    if v < 0.0 {
        -m
    } else {
        m
    }
}

/// 8-wide twin of [`tanh_fast`]: the same plain-op sequence, verbatim.
#[inline]
fn tanh_fast_simd(v: F8) -> F8 {
    let a = v.abs();
    let t = (F8::splat(2.0) * a).simd_min(F8::splat(TANH_CLAMP));
    let u = t * F8::splat(LOG2_E);
    let n = (u + F8::splat(0.5)).floor();
    let w = (u - n) * F8::splat(LN_2);
    let mut e = F8::splat(EXP_C7);
    e = e * w + F8::splat(EXP_C6);
    e = e * w + F8::splat(EXP_C5);
    e = e * w + F8::splat(EXP_C4);
    e = e * w + F8::splat(EXP_C3);
    e = e * w + F8::splat(EXP_C2);
    e = e * w + F8::splat(1.0);
    e = e * w + F8::splat(1.0);
    let scale =
        F8::from_bits((n.cast::<i32>() + Simd::splat(127i32)).cast::<u32>() << Simd::splat(23u32));
    let m = F8::splat(1.0) - F8::splat(2.0) / (e * scale + F8::splat(1.0));
    v.simd_lt(F8::splat(0.0)).select(-m, m)
}

/// Fast tanh-approximated GELU — the forward hot path. Same constants
/// and outer expression as [`gelu`], with [`tanh_fast`] replacing libm
/// tanh; within 1e-6 (relative for |x| > 1) of the oracle everywhere,
/// exact at 0 and in the saturated tails.
#[inline]
pub fn gelu_fast(x: f32) -> f32 {
    0.5 * x * (1.0 + tanh_fast(SQRT_2_OVER_PI * (x + GELU_C * x * x * x)))
}

/// 8-wide twin of [`gelu_fast`] — identical expression order.
#[inline]
fn gelu_fast_simd(x: F8) -> F8 {
    F8::splat(0.5)
        * x
        * (F8::splat(1.0)
            + tanh_fast_simd(
                F8::splat(SQRT_2_OVER_PI) * (x + F8::splat(GELU_C) * x * x * x),
            ))
}

/// The one fused-forward inner loop every GEGLU entry point shares:
/// `o[i] = gelu(z1[i]) * z2[i]` over contiguous slices, 8-wide SIMD
/// main body plus a scalar tail that computes bitwise-identical values
/// (see the module doc for why that equivalence is load-bearing).
#[inline]
fn geglu_lane(z1: &[f32], z2: &[f32], o: &mut [f32]) {
    let n = o.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        let a = F8::from_slice(&z1[i..i + LANES]);
        let b = F8::from_slice(&z2[i..i + LANES]);
        (gelu_fast_simd(a) * b).copy_to_slice(&mut o[i..i + LANES]);
        i += LANES;
    }
    for i in main..n {
        o[i] = gelu_fast(z1[i]) * z2[i];
    }
}

/// Derivative of the tanh-approximated GELU.
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let inner = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x * x)
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// A column-major (p, c) matrix: element (i, j) lives at data[j * p + i].
/// This is exactly the layout a 2:4-spMM epilogue leaves behind.
#[derive(Clone, Debug, PartialEq)]
pub struct ColMajor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl ColMajor {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        ColMajor { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_row_major(t: &Tensor) -> Self {
        let (r, c) = t.dims2();
        let mut out = ColMajor::zeros(r, c);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = t.data[i * c + j];
            }
        }
        out
    }

    pub fn to_row_major(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[i * self.cols + j] = self.data[j * self.rows + i];
            }
        }
        out
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[j * self.rows + i]
    }
}

/// Shared column-order GEGLU core: `z` is a (p, 2r) column-major flat
/// buffer (column j at `z[j*p..]`), `out` a (p, r) column-major one.
/// Every slice touched is contiguous — this is the paper's §5.2 kernel.
fn geglu_cols(z: &[f32], p: usize, r: usize, out: &mut [f32]) {
    for j in 0..r {
        let z1 = &z[j * p..(j + 1) * p];
        let z2 = &z[(r + j) * p..(r + j + 1) * p];
        let o = &mut out[j * p..(j + 1) * p];
        geglu_lane(z1, z2, o);
    }
}

/// "Ours" (paper §5.2): traverse along COLUMNS — contiguous in the
/// column-major layout, cache-friendly. Z: (p, 2r) -> out: (p, r).
pub fn geglu_col_order(z: &ColMajor) -> ColMajor {
    let p = z.rows;
    let r = z.cols / 2;
    let mut out = ColMajor::zeros(p, r);
    geglu_cols(&z.data, p, r, &mut out.data);
    out
}

/// Column-major fused GEGLU for the sparse FFN substrate: `zt` is Z^T
/// (2r, p) row-major — i.e. Z (p, 2r) column-major, exactly what the
/// `_cm` spMM epilogues produce — and `out` becomes A^T (r, p).
/// Allocation-free; per-element arithmetic identical to
/// [`geglu_row_major_into`], so switching layouts never moves a float.
pub fn geglu_cm_into(zt: &Tensor, out: &mut Tensor) {
    let (c2, p) = zt.dims2();
    let r = c2 / 2;
    out.resize_to(&[r, p]);
    geglu_cols(&zt.data, p, r, &mut out.data);
}

/// Backward of the column-major GEGLU: `zt` = Z^T (2r, p), `g` = ∇A^T
/// (r, p), `out` = ∇Z^T (2r, p). Column-order traversal: the gradient
/// streams contiguously exactly like the forward (Table 4's locality
/// argument applies to the backward too). Per-element arithmetic is
/// identical to [`geglu_row_major_grad_into`].
pub fn geglu_cm_grad_into(zt: &Tensor, g: &Tensor, out: &mut Tensor) {
    let (c2, p) = zt.dims2();
    let r = c2 / 2;
    assert_eq!(g.dims2(), (r, p));
    out.resize_to(&[c2, p]);
    // ∇Z1 fills rows 0..r, ∇Z2 rows r..2r — split once, then every
    // column access below is a contiguous p-slice
    let (o1s, o2s) = out.data.split_at_mut(r * p);
    for j in 0..r {
        let z1 = &zt.data[j * p..(j + 1) * p];
        let z2 = &zt.data[(r + j) * p..(r + j + 1) * p];
        let grow = &g.data[j * p..(j + 1) * p];
        let o1 = &mut o1s[j * p..(j + 1) * p];
        let o2 = &mut o2s[j * p..(j + 1) * p];
        for i in 0..p {
            o1[i] = gelu_grad(z1[i]) * z2[i] * grow[i];
            o2[i] = gelu(z1[i]) * grow[i];
        }
    }
}

/// "Intuitive" baseline: traverse along ROWS — strided by p in the
/// column-major layout; every access is a potential cache miss. Kept
/// deliberately row-ordered (this is the baseline under test in Table
/// 4), and on scalar [`gelu_fast`] so both traversal orders evaluate
/// the identical per-element arithmetic — Table 4 keeps measuring the
/// cache effect, not an activation-function difference.
pub fn geglu_row_order(z: &ColMajor) -> ColMajor {
    let p = z.rows;
    let r = z.cols / 2;
    let mut out = ColMajor::zeros(p, r);
    for i in 0..p {
        for j in 0..r {
            let a = z.data[j * p + i];
            let b = z.data[(r + j) * p + i];
            out.data[j * p + i] = gelu_fast(a) * b;
        }
    }
    out
}

/// SwiGLU, column-order — the paper benches both gated activations in
/// Table 4; the FFN substrates are GEGLU-only, so this kernel exists
/// for the bench/ablation surface, not the training path.
pub fn swiglu_col_order(z: &ColMajor) -> ColMajor {
    let p = z.rows;
    let r = z.cols / 2;
    let mut out = ColMajor::zeros(p, r);
    for j in 0..r {
        let z1 = &z.data[j * p..(j + 1) * p];
        let z2 = &z.data[(r + j) * p..(r + j + 1) * p];
        let o = &mut out.data[j * p..(j + 1) * p];
        for i in 0..p {
            o[i] = silu(z1[i]) * z2[i];
        }
    }
    out
}

/// Row-major fused GEGLU for the substrate paths that keep row-major
/// activations (FFN forward on the dense baseline). z: (p, 2r) row-major.
pub fn geglu_row_major(z: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[0]);
    geglu_row_major_into(z, &mut out);
    out
}

/// Allocation-free variant: `out` is reshaped to (p, r) and overwritten.
pub fn geglu_row_major_into(z: &Tensor, out: &mut Tensor) {
    let (p, c2) = z.dims2();
    let r = c2 / 2;
    out.resize_to(&[p, r]);
    for i in 0..p {
        let zrow = &z.data[i * c2..(i + 1) * c2];
        let orow = &mut out.data[i * r..(i + 1) * r];
        let (z1, z2) = zrow.split_at(r);
        geglu_lane(z1, z2, orow);
    }
}

/// Backward of row-major GEGLU: given z and upstream g (p, r), return
/// gradient wrt z (p, 2r).
pub fn geglu_row_major_grad(z: &Tensor, g: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[0]);
    geglu_row_major_grad_into(z, g, &mut out);
    out
}

/// Allocation-free variant: `out` is reshaped to (p, 2r) and overwritten.
pub fn geglu_row_major_grad_into(z: &Tensor, g: &Tensor, out: &mut Tensor) {
    let (p, c2) = z.dims2();
    let r = c2 / 2;
    assert_eq!(g.dims2(), (p, r));
    out.resize_to(&[p, c2]);
    for i in 0..p {
        let zrow = &z.data[i * c2..(i + 1) * c2];
        let grow = &g.data[i * r..(i + 1) * r];
        let orow = &mut out.data[i * c2..(i + 1) * c2];
        for j in 0..r {
            let (z1, z2) = (zrow[j], zrow[r + j]);
            orow[j] = gelu_grad(z1) * z2 * grow[j];
            orow[r + j] = gelu(z1) * grow[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(100.0) - 100.0).abs() < 1e-3);
        assert!(gelu(-100.0).abs() < 1e-3);
        // antisymmetric identity: gelu(x) - gelu(-x) == x (holds exactly
        // for the tanh approximation too)
        for &x in &[0.5f32, 1.0, 2.0, 3.0] {
            assert!((gelu(x) - gelu(-x) - x).abs() < 1e-5);
        }
    }

    #[test]
    fn gelu_fast_matches_libm_oracle_within_1e6() {
        // dense sweep over the live range plus far-tail points; 1e-6
        // absolute below |x| = 1, relative above
        let mut xs: Vec<f32> = (-8000..=8000).map(|i| i as f32 * 1e-3).collect();
        xs.extend_from_slice(&[-100.0, -20.0, -12.5, 12.5, 20.0, 100.0]);
        for x in xs {
            let (fast, oracle) = (gelu_fast(x), gelu(x));
            let tol = 1e-6f32.max(1e-6 * x.abs());
            assert!(
                (fast - oracle).abs() <= tol,
                "x={x}: fast={fast} oracle={oracle}"
            );
        }
    }

    #[test]
    fn gelu_fast_saturates_exactly() {
        // past the tanh clamp the identity branch must be EXACT, like
        // libm tanh's saturation: gelu(x) = x, gelu(-x) = 0
        for &x in &[15.0f32, 50.0, 100.0, 1e4] {
            assert_eq!(gelu_fast(x), x);
            assert_eq!(gelu_fast(-x), 0.0);
        }
        assert_eq!(gelu_fast(0.0), 0.0);
    }

    #[test]
    fn geglu_lane_simd_body_matches_scalar_tail_bitwise() {
        // odd lengths force every element through the SIMD body in one
        // run and the scalar tail in another; results must be bitwise
        // equal or the cm/row-major cross-layout identities break
        let mut rng = Rng::new(99);
        for n in [1usize, 7, 8, 9, 23, 64, 65] {
            let z1 = Tensor::normal(&[1, n], 2.0, &mut rng);
            let z2 = Tensor::normal(&[1, n], 2.0, &mut rng);
            let mut out = vec![0.0f32; n];
            geglu_lane(&z1.data, &z2.data, &mut out);
            for i in 0..n {
                let want = gelu_fast(z1.data[i]) * z2.data[i];
                assert_eq!(
                    out[i].to_bits(),
                    want.to_bits(),
                    "n={n} i={i}: {} vs {want}",
                    out[i]
                );
            }
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn silu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, 0.0, 1.3] {
            let h = 1e-3f32;
            let fd = (silu(x + h) - silu(x - h)) / (2.0 * h);
            assert!((silu_grad(x) - fd).abs() < 1e-3);
        }
    }

    #[test]
    fn col_major_roundtrip() {
        let mut rng = Rng::new(0);
        let t = Tensor::normal(&[5, 7], 1.0, &mut rng);
        assert_eq!(ColMajor::from_row_major(&t).to_row_major(), t);
    }

    #[test]
    fn row_and_col_order_agree() {
        let mut rng = Rng::new(1);
        let z = ColMajor::from_row_major(&Tensor::normal(&[16, 32], 1.0, &mut rng));
        let a = geglu_col_order(&z);
        let b = geglu_row_order(&z);
        assert_eq!(a.rows, 16);
        assert_eq!(a.cols, 16);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn col_order_matches_row_major_kernel() {
        let mut rng = Rng::new(2);
        let z_rm = Tensor::normal(&[8, 12], 1.0, &mut rng);
        let via_cm = geglu_col_order(&ColMajor::from_row_major(&z_rm)).to_row_major();
        let direct = geglu_row_major(&z_rm);
        assert!(via_cm.max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn cm_kernels_match_row_major_bitwise() {
        // geglu_cm_into / geglu_cm_grad_into run the same per-element
        // arithmetic as the row-major kernels — the transposed results
        // must agree BITWISE, not just to tolerance
        let mut rng = Rng::new(7);
        let z_rm = Tensor::normal(&[9, 14], 1.0, &mut rng);
        let g_rm = Tensor::normal(&[9, 7], 1.0, &mut rng);
        let zt = z_rm.t();
        let gt = g_rm.t();
        let mut a_cm = Tensor::zeros(&[0]);
        geglu_cm_into(&zt, &mut a_cm);
        assert_eq!(a_cm.dims2(), (7, 9));
        assert_eq!(a_cm.t(), geglu_row_major(&z_rm));
        let mut dz_cm = Tensor::zeros(&[0]);
        geglu_cm_grad_into(&zt, &gt, &mut dz_cm);
        assert_eq!(dz_cm.dims2(), (14, 9));
        assert_eq!(dz_cm.t(), geglu_row_major_grad(&z_rm, &g_rm));
    }

    #[test]
    fn cm_forward_matches_col_order_kernel() {
        // the FFN-substrate entry point and the Table-4 bench kernel
        // share one inner loop; pin that they stay identical
        let mut rng = Rng::new(8);
        let z_rm = Tensor::normal(&[6, 10], 1.0, &mut rng);
        let via_bench = geglu_col_order(&ColMajor::from_row_major(&z_rm));
        let mut via_ffn = Tensor::zeros(&[0]);
        geglu_cm_into(&z_rm.t(), &mut via_ffn);
        assert_eq!(via_ffn.data, via_bench.data);
    }

    #[test]
    fn geglu_grad_finite_difference() {
        let mut rng = Rng::new(3);
        let z = Tensor::normal(&[2, 8], 1.0, &mut rng);
        let g = Tensor::ones(&[2, 4]);
        let grad = geglu_row_major_grad(&z, &g);
        let h = 1e-3f32;
        for k in 0..z.len() {
            let mut zp = z.clone();
            zp.data[k] += h;
            let mut zm = z.clone();
            zm.data[k] -= h;
            let fd: f32 = (geglu_row_major(&zp).sum() - geglu_row_major(&zm).sum()) as f32
                / (2.0 * h);
            assert!((grad.data[k] - fd).abs() < 2e-2, "k={k} {} vs {fd}", grad.data[k]);
        }
    }

    #[test]
    fn zero_gate_zeroes_output() {
        let mut z = Tensor::zeros(&[2, 8]);
        for j in 0..4 {
            z.data[j] = 1.0; // z1 nonzero, z2 (gate) zero
        }
        assert_eq!(geglu_row_major(&z).data, vec![0.0; 8]);
    }
}
