//! Flip rate (Definition 4.1) and per-block flip statistics (Fig. 1-3).
//!
//! The flip rate r_t = ||m(w_t) - m(w_{t-1})||_1 / D monitors how fast the
//! sparse connectivity is changing. The paper's health criterion: r_t
//! should RISE early (explore connection modes) then FADE to ~0 (converge);
//! sustained r_t above the dense baseline ("flip-rate explosion") predicts
//! an accuracy loss (Table 1). `FlipMonitor` tracks the global rate;
//! `BlockFlipStats` reproduces the per-4x4-block scatter of Fig. 2
//! (cumulative flips vs. L1-norm gap between the two best masks).
//!
//! The activation-sparse workload family gets the same treatment:
//! [`ActFlipMonitor`] tracks per-step churn of the ACTIVATION 2:4
//! keep-masks (raw byte vectors in A^T layout, recorded by the forward
//! pass) and publishes it as the `sparse.flip.activation` gauge —
//! alongside the weight-mask churn the trainer publishes as
//! `sparse.flip.weight`. Activation masks are input-dependent, so their
//! churn is a property of the data distribution rather than of the
//! optimizer trajectory; tracking the two families separately is what
//! makes the cross-mode ablation legible.

use super::mask::{prune24_mask, Mask};
use super::transposable::{best_pattern, PATTERNS};
use crate::tensor::Tensor;

/// Definition 4.1 on explicit masks.
pub fn flip_rate(prev: &Mask, new: &Mask) -> f64 {
    prev.hamming(new) as f64 / prev.len() as f64
}

/// Running flip-rate monitor over one weight matrix.
///
/// Mirrors the paper's dense-baseline trick: for dense training the monitor
/// prunes a *copy* of the weights each step (the pruned weights are never
/// used), giving the "virtual" flip-rate curve dense training would have.
#[derive(Clone, Debug)]
pub struct FlipMonitor {
    prev: Option<Mask>,
    pub history: Vec<f64>,
}

impl FlipMonitor {
    pub fn new() -> Self {
        FlipMonitor { prev: None, history: Vec::new() }
    }

    /// Observe the current dense weights; returns r_t (0.0 on first call).
    pub fn observe(&mut self, w: &Tensor) -> f64 {
        let m = prune24_mask(w);
        let r = match &self.prev {
            Some(p) => flip_rate(p, &m),
            None => 0.0,
        };
        self.prev = Some(m);
        self.history.push(r);
        r
    }

    /// Set the differencing baseline WITHOUT recording a history entry
    /// (checkpoint resume: re-seed from the restored weights).
    pub fn seed_from(&mut self, w: &Tensor) {
        self.prev = Some(prune24_mask(w));
    }

    /// Observe an externally computed mask (e.g. the transposable one).
    pub fn observe_mask(&mut self, m: Mask) -> f64 {
        let r = match &self.prev {
            Some(p) => flip_rate(p, &m),
            None => 0.0,
        };
        self.prev = Some(m);
        self.history.push(r);
        r
    }

    pub fn last(&self) -> f64 {
        *self.history.last().unwrap_or(&0.0)
    }

    /// Mean flip rate over a window (the tuner's sampled statistic, §4.3).
    pub fn mean_over(&self, last_n: usize) -> f64 {
        if self.history.is_empty() {
            return 0.0;
        }
        let n = last_n.min(self.history.len());
        let s: f64 = self.history[self.history.len() - n..].iter().sum();
        s / n as f64
    }

    /// Paper's health criterion: peak early, tail low.
    /// Returns (peak, tail_mean, healthy).
    pub fn health(&self, tail_frac: f64) -> (f64, f64, bool) {
        if self.history.len() < 4 {
            return (0.0, 0.0, true);
        }
        let peak = self.history.iter().cloned().fold(0.0, f64::max);
        let tail_n = ((self.history.len() as f64) * tail_frac).max(1.0) as usize;
        let tail = self.mean_over(tail_n);
        (peak, tail, tail < 0.5 * peak + 1e-12)
    }
}

impl Default for FlipMonitor {
    fn default() -> Self {
        Self::new()
    }
}

/// Running churn monitor for the activation 2:4 keep-masks.
///
/// Activation masks live as raw keep-byte vectors in A^T (r, p) layout
/// ([`crate::sparse::ffn::FfnCache::act_mask`]), not as weight
/// [`Mask`]es: they are rebuilt from live activations every step, so
/// their churn measures input/representation drift, not optimizer
/// motion. Each observation publishes the `sparse.flip.activation`
/// gauge when metrics are on.
#[derive(Clone, Debug, Default)]
pub struct ActFlipMonitor {
    prev: Vec<u8>,
    pub history: Vec<f64>,
}

impl ActFlipMonitor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observe the current activation keep-mask; returns r_t (0.0 on
    /// the first call, and whenever the batch shape changed — masks of
    /// different lengths are not comparable).
    pub fn observe(&mut self, mask: &[u8]) -> f64 {
        let r = if !mask.is_empty() && self.prev.len() == mask.len() {
            let flips = self.prev.iter().zip(mask).filter(|(a, b)| a != b).count();
            flips as f64 / mask.len() as f64
        } else {
            0.0
        };
        self.prev.clear();
        self.prev.extend_from_slice(mask);
        self.history.push(r);
        if crate::obs::metrics_on() {
            crate::obs::gauge("sparse.flip.activation").set(r);
        }
        r
    }

    pub fn last(&self) -> f64 {
        *self.history.last().unwrap_or(&0.0)
    }

    /// Mean flip rate over a window (same statistic as
    /// [`FlipMonitor::mean_over`], on the activation family).
    pub fn mean_over(&self, last_n: usize) -> f64 {
        if self.history.is_empty() {
            return 0.0;
        }
        let n = last_n.min(self.history.len());
        let s: f64 = self.history[self.history.len() - n..].iter().sum();
        s / n as f64
    }
}

/// Per-4x4-block statistics for the Fig. 2 scatter: cumulative flip count
/// and the "L1 norm gap" g_i = ||m1 ⊙ w||_1 - ||m2 ⊙ w||_1 between the
/// best and second-best transposable patterns of each block.
#[derive(Clone, Debug)]
pub struct BlockFlipStats {
    pub block_rows: usize,
    pub block_cols: usize,
    /// cumulative number of mask flips per block (any bit change counts 1)
    pub flips: Vec<u64>,
    prev_pattern: Vec<usize>,
    initialized: bool,
}

impl BlockFlipStats {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(rows % 4 == 0 && cols % 4 == 0);
        let n = (rows / 4) * (cols / 4);
        BlockFlipStats {
            block_rows: rows / 4,
            block_cols: cols / 4,
            flips: vec![0; n],
            prev_pattern: vec![usize::MAX; n],
            initialized: false,
        }
    }

    /// Observe current weights; count a flip for every block whose optimal
    /// transposable pattern changed since the last observation.
    pub fn observe(&mut self, w: &Tensor) {
        let (r, c) = w.dims2();
        assert_eq!((r / 4, c / 4), (self.block_rows, self.block_cols));
        let mut block = [0f32; 16];
        for bi in 0..self.block_rows {
            for bj in 0..self.block_cols {
                for k in 0..4 {
                    for l in 0..4 {
                        block[k * 4 + l] = w.data[(bi * 4 + k) * c + bj * 4 + l].abs();
                    }
                }
                let pat = best_pattern(&block);
                let idx = bi * self.block_cols + bj;
                if self.initialized && self.prev_pattern[idx] != pat {
                    self.flips[idx] += 1;
                }
                self.prev_pattern[idx] = pat;
            }
        }
        self.initialized = true;
    }

    /// L1-norm gap per block: best minus second-best pattern score.
    /// Small gap + high flip count = the paper's "dilemma point".
    pub fn l1_gaps(&self, w: &Tensor) -> Vec<f64> {
        let (_, c) = w.dims2();
        let mut out = Vec::with_capacity(self.flips.len());
        let mut block = [0f32; 16];
        for bi in 0..self.block_rows {
            for bj in 0..self.block_cols {
                for k in 0..4 {
                    for l in 0..4 {
                        block[k * 4 + l] = w.data[(bi * 4 + k) * c + bj * 4 + l].abs();
                    }
                }
                let (mut s1, mut s2) = (f32::MIN, f32::MIN);
                for pat in PATTERNS.iter() {
                    let mut s = 0f32;
                    for k in 0..16 {
                        s += pat[k] * block[k];
                    }
                    if s > s1 {
                        s2 = s1;
                        s1 = s;
                    } else if s > s2 {
                        s2 = s;
                    }
                }
                out.push((s1 - s2) as f64);
            }
        }
        out
    }

    /// (cumulative flips, current L1 gap) rows for the Fig. 2 scatter.
    pub fn scatter(&self, w: &Tensor) -> Vec<(u64, f64)> {
        self.flips.iter().cloned().zip(self.l1_gaps(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn flip_rate_zero_for_identical_masks() {
        let m = Mask::ones(4, 8);
        assert_eq!(flip_rate(&m, &m.clone()), 0.0);
    }

    #[test]
    fn flip_rate_range_and_symmetry() {
        let a = Mask { rows: 1, cols: 4, data: vec![1, 1, 0, 0] };
        let b = Mask { rows: 1, cols: 4, data: vec![0, 0, 1, 1] };
        assert_eq!(flip_rate(&a, &b), 1.0);
        assert_eq!(flip_rate(&b, &a), 1.0);
    }

    #[test]
    fn monitor_first_observation_is_zero() {
        let mut mon = FlipMonitor::new();
        let mut rng = Rng::new(0);
        let w = Tensor::normal(&[8, 16], 1.0, &mut rng);
        assert_eq!(mon.observe(&w), 0.0);
        // same weights -> no flips
        assert_eq!(mon.observe(&w), 0.0);
    }

    #[test]
    fn monitor_detects_changes() {
        let mut mon = FlipMonitor::new();
        let mut rng = Rng::new(1);
        let w1 = Tensor::normal(&[8, 16], 1.0, &mut rng);
        let w2 = Tensor::normal(&[8, 16], 1.0, &mut rng);
        mon.observe(&w1);
        let r = mon.observe(&w2);
        assert!(r > 0.0 && r <= 1.0);
        assert_eq!(mon.history.len(), 2);
    }

    #[test]
    fn health_passes_for_decaying_curve() {
        let mut mon = FlipMonitor::new();
        mon.history = vec![0.0, 0.2, 0.4, 0.3, 0.1, 0.02, 0.01, 0.01];
        let (peak, tail, healthy) = mon.health(0.25);
        assert_eq!(peak, 0.4);
        assert!(tail < 0.05);
        assert!(healthy);
    }

    #[test]
    fn health_fails_for_exploding_curve() {
        let mut mon = FlipMonitor::new();
        mon.history = vec![0.1, 0.2, 0.3, 0.35, 0.4, 0.42, 0.45, 0.5];
        let (_, _, healthy) = mon.health(0.25);
        assert!(!healthy);
    }

    #[test]
    fn act_monitor_first_observation_and_shape_changes_are_zero() {
        let mut mon = ActFlipMonitor::new();
        assert_eq!(mon.observe(&[1, 1, 0, 0]), 0.0);
        // identical mask -> no flips
        assert_eq!(mon.observe(&[1, 1, 0, 0]), 0.0);
        // shape change -> not comparable, resets to 0
        assert_eq!(mon.observe(&[1, 0, 0, 1, 1, 0, 0, 1]), 0.0);
        assert_eq!(mon.history.len(), 3);
    }

    #[test]
    fn act_monitor_counts_byte_flips() {
        let mut mon = ActFlipMonitor::new();
        mon.observe(&[1, 1, 0, 0]);
        // two of four bytes changed
        assert_eq!(mon.observe(&[1, 0, 1, 0]), 0.5);
        assert_eq!(mon.last(), 0.5);
        assert_eq!(mon.mean_over(2), 0.25);
    }

    #[test]
    fn block_stats_count_pattern_changes() {
        let mut rng = Rng::new(2);
        let w1 = Tensor::normal(&[8, 8], 1.0, &mut rng);
        let mut stats = BlockFlipStats::new(8, 8);
        stats.observe(&w1);
        stats.observe(&w1); // unchanged -> no flips
        assert!(stats.flips.iter().all(|&f| f == 0));
        let w2 = Tensor::normal(&[8, 8], 1.0, &mut rng);
        stats.observe(&w2);
        assert!(stats.flips.iter().any(|&f| f > 0));
    }

    #[test]
    fn l1_gap_nonnegative() {
        let mut rng = Rng::new(3);
        let w = Tensor::normal(&[8, 12], 1.0, &mut rng);
        let stats = BlockFlipStats::new(8, 12);
        assert!(stats.l1_gaps(&w).iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn scatter_dimensions() {
        let mut rng = Rng::new(4);
        let w = Tensor::normal(&[8, 8], 1.0, &mut rng);
        let mut stats = BlockFlipStats::new(8, 8);
        stats.observe(&w);
        assert_eq!(stats.scatter(&w).len(), 4);
    }
}
