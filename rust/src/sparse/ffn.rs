//! FFN layer on the CPU substrate — dense vs. FST 2:4 (Fig. 7a, Table 13).
//!
//! Implements the paper's full per-iteration FFN workflow (Appendix B):
//!
//!   forward:   Z = X (W1 ⊙ M1)^T + b1;  A = GEGLU(Z);  Y = A (W2 ⊙ M2)^T + b2
//!   backward:  ∇W2 = MVUE(∇Y^T) A        (spmm_tn, Eq. 4+6)
//!              ∇A  = ∇Y (W2 ⊙ M2)        (spmm_nt via compressed W^T, Eq. 3)
//!              ∇Z  = GEGLU'(Z) ∘ ∇A
//!              ∇W1 = MVUE(∇Z^T) X
//!              ∇X  = ∇Z (W1 ⊙ M1)
//!
//! plus the per-step weight (re)compression and the every-l-steps
//! transposable-mask search. The dense twin runs the same shapes through
//! dense GEMMs.
//!
//! **Sparse modes.** The 2:4 machinery serves two operand families,
//! selected by [`SparseMode`]. `Weight` is the paper's FST pipeline
//! above, byte for byte. `Activation` keeps the weights dense and
//! instead 2:4-prunes the post-GEGLU activation per token
//! ([`prune_act24_cm`]): each group of four consecutive hidden lanes
//! keeps its top-2 magnitude pair, the survivors are packed through the
//! same [`Compressed24`] representation, and the second FFN matmul runs
//! with the *activation* operand compressed-stationary
//! ([`crate::sparse::kernels::spmm_tn_cm_into`]). Its backward is
//! straight-through: ∇A is masked to the surviving lanes and everything
//! downstream is a dense GEMM. `Both` stacks activation pruning on the
//! weight pipeline — the weight operand keeps the compressed slot (the
//! spMM, like sparse tensor cores, structures one operand), so the
//! pruned activation streams through dense with its lanes zeroed.
//!
//! **Layout (paper Appendix A.2, Table 12):** on the sparse paths every
//! interior activation is COLUMN-major. The first spMM's fused epilogue
//! leaves Z as Z^T ([`crate::sparse::kernels::spmm_nt_cm_into`]), the
//! column-order GEGLU consumes it in place, and the second spMM takes
//! A^T as its pre-transposed streaming operand directly — no tensor is
//! ever materialized in a layout the next op has to undo. Conversion to
//! row-major happens exactly once, folded into the epilogue of the spMM
//! that crosses the block boundary (Y, ∇X), where attention needs rows.
//! The backward gets the same treatment: ∇Z^T is *born* transposed, so
//! the MVUE weight-grad estimator reads it with zero staging. The dense
//! twin stays row-major throughout (its GEMMs are row-major native).
//!
//! The `_scratch` variants are the hot path: every output/temporary is a
//! caller-owned buffer recycled through a [`Scratch`] arena, so the
//! steady state performs zero heap allocations — the Fig. 7 benches
//! measure kernel arithmetic, not the allocator. The plain
//! `forward`/`backward` wrappers allocate and delegate.

use super::gemm::{gemm_nn_into, gemm_nt_into, gemm_tn_into};
use super::geglu::{
    geglu_cm_grad_into, geglu_cm_into, geglu_row_major_grad_into,
    geglu_row_major_into,
};
use super::kernels::{self, with_thread_scratch, Scratch};
use super::mask::{top2_of4, Mask};
use super::mvue::mvue24_into;
use super::spmm::{spmm_tn_into, Compressed24};
use super::transposable::transposable_mask;
use super::SparseMode;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Gradients of one FFN layer.
#[derive(Debug)]
pub struct FfnGrads {
    pub dx: Tensor,
    pub dw1: Tensor,
    pub db1: Tensor,
    pub dw2: Tensor,
    pub db2: Tensor,
}

impl FfnGrads {
    /// Empty gradient buffers, shaped on first use by the `_scratch` paths.
    pub fn empty() -> FfnGrads {
        FfnGrads {
            dx: Tensor::zeros(&[0]),
            dw1: Tensor::zeros(&[0]),
            db1: Tensor::zeros(&[0]),
            dw2: Tensor::zeros(&[0]),
            db2: Tensor::zeros(&[0]),
        }
    }
}

/// Dense FFN layer: W1 (2r, d), W2 (d, r), gated activation.
#[derive(Clone, Debug)]
pub struct DenseFfn {
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
}

/// Forward cache reused by the backward pass (recycled across steps by
/// the `_scratch` paths).
///
/// Layout depends on the owner: [`DenseFfn`] stores `z` (p, 2r) and `a`
/// (p, r) row-major; [`SparseFfn`] stores them COLUMN-major as Z^T
/// (2r, p) and A^T (r, p) — the Table-12 layout its spMM epilogues
/// produce and its backward consumes in place.
pub struct FfnCache {
    pub z: Tensor,
    pub a: Tensor,
    /// Activation keep-mask in A^T layout (r, p), one byte per element,
    /// 1 = lane survived 2:4 pruning. Written by the activation-sparse
    /// forward ([`prune_act24_cm`]); the straight-through backward
    /// applies it to ∇A^T. Empty in `Weight` mode.
    pub act_mask: Vec<u8>,
    /// Compressed activation A (p tokens × r lanes, row-major groups),
    /// the stationary operand of the second matmul in `Activation`
    /// mode. Capacity is recycled across steps. Empty in other modes.
    pub acomp: Compressed24,
}

impl FfnCache {
    pub fn empty() -> FfnCache {
        FfnCache {
            z: Tensor::zeros(&[0]),
            a: Tensor::zeros(&[0]),
            act_mask: Vec::new(),
            acomp: Compressed24::default(),
        }
    }
}

impl DenseFfn {
    pub fn new(d: usize, r: usize, rng: &mut Rng) -> Self {
        DenseFfn {
            w1: Tensor::normal(&[2 * r, d], 0.02, rng),
            b1: Tensor::zeros(&[2 * r]),
            w2: Tensor::normal(&[d, r], 0.02, rng),
            b2: Tensor::zeros(&[d]),
        }
    }

    pub fn forward(&self, x: &Tensor) -> (Tensor, FfnCache) {
        let mut cache = FfnCache::empty();
        let mut y = Tensor::zeros(&[0]);
        self.forward_scratch(x, &mut cache, &mut y);
        (y, cache)
    }

    /// Zero-allocation forward: `cache` and `y` are reshaped in place.
    pub fn forward_scratch(&self, x: &Tensor, cache: &mut FfnCache, y: &mut Tensor) {
        let (p, _) = x.dims2();
        let (two_r, _) = self.w1.dims2();
        let (d, _) = self.w2.dims2();
        cache.z.resize_to(&[p, two_r]);
        gemm_nt_into(x, &self.w1, &mut cache.z);
        add_bias(&mut cache.z, &self.b1);
        geglu_row_major_into(&cache.z, &mut cache.a);
        y.resize_to(&[p, d]);
        gemm_nt_into(&cache.a, &self.w2, y);
        add_bias(y, &self.b2);
    }

    pub fn backward(&self, x: &Tensor, cache: &FfnCache, dy: &Tensor) -> FfnGrads {
        let mut g = FfnGrads::empty();
        let mut s = Scratch::new();
        self.backward_scratch(x, cache, dy, &mut g, &mut s);
        g
    }

    /// Zero-allocation backward: gradients land in `g`, temporaries come
    /// from `scratch`.
    pub fn backward_scratch(
        &self,
        x: &Tensor,
        cache: &FfnCache,
        dy: &Tensor,
        g: &mut FfnGrads,
        scratch: &mut Scratch,
    ) {
        let (p, _) = x.dims2();
        let (_, r) = self.w2.dims2();
        let (two_r, _) = self.w1.dims2();
        g.dw2.resize_to(&self.w2.shape);
        gemm_tn_into(dy, &cache.a, &mut g.dw2);
        col_sum_into(dy, &mut g.db2);
        let mut da = scratch.take(&[p, r]);
        gemm_nn_into(dy, &self.w2, &mut da);
        let mut dz = scratch.take(&[p, two_r]);
        geglu_row_major_grad_into(&cache.z, &da, &mut dz);
        g.dw1.resize_to(&self.w1.shape);
        gemm_tn_into(&dz, x, &mut g.dw1);
        col_sum_into(&dz, &mut g.db1);
        g.dx.resize_to(&x.shape);
        gemm_nn_into(&dz, &self.w1, &mut g.dx);
        scratch.give(da);
        scratch.give(dz);
    }
}

/// FST 2:4 FFN layer: dense master weights + transposable masks +
/// compressed operands, refreshed per the paper's schedule.
#[derive(Clone, Debug)]
pub struct SparseFfn {
    pub dense: DenseFfn,
    pub m1: Mask,
    pub m2: Mask,
    /// transposed masks, cached so per-step recompression allocates nothing
    pub m1t: Mask,
    pub m2t: Mask,
    pub w1c: Compressed24,
    pub w2c: Compressed24,
    /// compressed TRANSPOSES — the transposable masks (Eq. 5) guarantee
    /// W^T ⊙ M^T is also row-wise 2:4, so the backward input-grad GEMM
    /// (Eq. 3) runs through the same fast spmm_nt kernel. This is exactly
    /// the property the paper's transposable-mask machinery buys.
    pub w1ct: Compressed24,
    pub w2ct: Compressed24,
    /// Which operand family is pruned; see [`SparseMode`]. `Weight`
    /// preserves the pre-mode pipeline byte for byte.
    pub mode: SparseMode,
}

impl SparseFfn {
    pub fn new(d: usize, r: usize, rng: &mut Rng) -> Self {
        Self::new_with_mode(d, r, SparseMode::Weight, rng)
    }

    /// Build for an explicit [`SparseMode`]. `Activation` keeps the
    /// weights dense — the transposable-mask search and the four
    /// compressed weight operands (the dominant setup cost at real
    /// shapes) are skipped entirely — while `Weight`/`Both` run the
    /// full FST construction.
    pub fn new_with_mode(d: usize, r: usize, mode: SparseMode, rng: &mut Rng) -> Self {
        let dense = DenseFfn::new(d, r, rng);
        if !mode.sparse_weights() {
            return SparseFfn {
                dense,
                m1: Mask::zeros(0, 0),
                m2: Mask::zeros(0, 0),
                m1t: Mask::zeros(0, 0),
                m2t: Mask::zeros(0, 0),
                w1c: Compressed24::default(),
                w2c: Compressed24::default(),
                w1ct: Compressed24::default(),
                w2ct: Compressed24::default(),
                mode,
            };
        }
        let m1 = transposable_mask(&dense.w1);
        let m2 = transposable_mask(&dense.w2);
        let m1t = m1.transpose();
        let m2t = m2.transpose();
        let w1c = Compressed24::from_masked(&dense.w1, &m1);
        let w2c = Compressed24::from_masked(&dense.w2, &m2);
        let w1ct = Compressed24::from_masked(&dense.w1.t(), &m1t);
        let w2ct = Compressed24::from_masked(&dense.w2.t(), &m2t);
        SparseFfn { dense, m1, m2, m1t, m2t, w1c, w2c, w1ct, w2ct, mode }
    }

    /// Per-step "prune weights": recompress values under the CURRENT masks
    /// (cheap; Table 13's `Prune weights` row). Zero-allocation: the
    /// compressed buffers and the transpose temporary are reused.
    /// No-op in `Activation` mode (there are no weight masks).
    pub fn recompress(&mut self) {
        if !self.mode.sparse_weights() {
            return;
        }
        self.w1c.from_masked_into(&self.dense.w1, &self.m1);
        self.w2c.from_masked_into(&self.dense.w2, &self.m2);
        let (r1, c1) = self.dense.w1.dims2();
        let (r2, c2) = self.dense.w2.dims2();
        let dense = &self.dense;
        let (w1ct, w2ct) = (&mut self.w1ct, &mut self.w2ct);
        let (m1t, m2t) = (&self.m1t, &self.m2t);
        with_thread_scratch(|s| {
            // one buffer per shape, both held until the end: steady-state
            // lengths never change, so the transpose targets are never
            // redundantly zeroed and best-fit reuse stays shape-stable
            let mut w1t = s.take(&[c1, r1]);
            let mut w2t = s.take(&[c2, r2]);
            kernels::transpose(&dense.w1, &mut w1t);
            w1ct.from_masked_into(&w1t, m1t);
            kernels::transpose(&dense.w2, &mut w2t);
            w2ct.from_masked_into(&w2t, m2t);
            s.give(w1t);
            s.give(w2t);
        });
    }

    /// Every-l-steps transposable mask search (Table 13's bottom row).
    /// No-op in `Activation` mode (there are no weight masks).
    pub fn refresh_masks(&mut self) {
        if !self.mode.sparse_weights() {
            return;
        }
        self.m1 = transposable_mask(&self.dense.w1);
        self.m2 = transposable_mask(&self.dense.w2);
        self.m1t = self.m1.transpose();
        self.m2t = self.m2.transpose();
        self.recompress();
    }

    pub fn forward(&self, x: &Tensor) -> (Tensor, FfnCache) {
        let mut cache = FfnCache::empty();
        let mut y = Tensor::zeros(&[0]);
        self.forward_scratch(x, &mut cache, &mut y);
        (y, cache)
    }

    /// Zero-allocation forward through the compressed operands, in the
    /// paper's Table-12 layout: Z and A live column-major in the cache
    /// (`cache.z` = Z^T, `cache.a` = A^T), the GEGLU streams columns,
    /// and only the last spMM's epilogue converts back to row-major for
    /// the block boundary. The one staging transpose left is X^T inside
    /// the first spMM — `x` arrives row-major from attention/LN.
    ///
    /// In `Activation` mode the first matmul is a dense GEMM whose
    /// output is born as Z^T (`gemm_nt_into(W1, X)` = W1 X^T), the
    /// GEGLU output is 2:4-pruned per token and packed
    /// ([`prune_act24_cm`]), and the second matmul streams the dense W2
    /// against the compressed-stationary activation. `Both` runs the
    /// weight pipeline with the activation pruned in place between the
    /// GEGLU and the second spMM.
    pub fn forward_scratch(&self, x: &Tensor, cache: &mut FfnCache, y: &mut Tensor) {
        let (p, _) = x.dims2();
        match self.mode {
            SparseMode::Weight => {
                cache.z.resize_to(&[self.w1c.rows, p]);
                kernels::spmm_nt_cm_into(x, &self.w1c, &mut cache.z);
                add_bias_cm(&mut cache.z, &self.dense.b1);
                geglu_cm_into(&cache.z, &mut cache.a);
                y.resize_to(&[p, self.w2c.rows]);
                kernels::spmm_nt_t_into(&cache.a, &self.w2c, y);
                add_bias(y, &self.dense.b2);
            }
            SparseMode::Activation => {
                let (two_r, _) = self.dense.w1.dims2();
                let (d, _) = self.dense.w2.dims2();
                cache.z.resize_to(&[two_r, p]);
                kernels::gemm_nt_into(&self.dense.w1, x, &mut cache.z);
                add_bias_cm(&mut cache.z, &self.dense.b1);
                geglu_cm_into(&cache.z, &mut cache.a);
                prune_act24_cm(
                    &mut cache.a,
                    Some(&mut cache.act_mask),
                    Some(&mut cache.acomp),
                );
                y.resize_to(&[p, d]);
                kernels::spmm_tn_cm_into(&cache.acomp, &self.dense.w2, y);
                add_bias(y, &self.dense.b2);
            }
            SparseMode::Both => {
                cache.z.resize_to(&[self.w1c.rows, p]);
                kernels::spmm_nt_cm_into(x, &self.w1c, &mut cache.z);
                add_bias_cm(&mut cache.z, &self.dense.b1);
                geglu_cm_into(&cache.z, &mut cache.a);
                // weight operand owns the compressed slot; the pruned
                // activation streams dense with its lanes zeroed
                prune_act24_cm(&mut cache.a, Some(&mut cache.act_mask), None);
                y.resize_to(&[p, self.w2c.rows]);
                kernels::spmm_nt_t_into(&cache.a, &self.w2c, y);
                add_bias(y, &self.dense.b2);
            }
        }
    }

    /// FST backward: MVUE-compressed gradient spMMs (Eq. 4+6) and
    /// masked-weight input-grad spMMs (Eq. 3).
    pub fn backward(&self, x: &Tensor, cache: &FfnCache, dy: &Tensor,
                    rng: &mut Rng) -> FfnGrads {
        let mut g = FfnGrads::empty();
        let mut s = Scratch::new();
        self.backward_scratch(x, cache, dy, rng, &mut g, &mut s);
        g
    }

    /// Zero-allocation FST backward. Draws the same MVUE uniform stream
    /// as [`SparseFfn::backward`] for a given rng state.
    ///
    /// Column-major pipeline: `dy` is transposed ONCE (it arrives
    /// row-major from the block boundary) and that ∇Y^T feeds both the
    /// MVUE weight-grad estimator and — as the pre-transposed streaming
    /// operand — the ∇A spMM, whose fused epilogue leaves ∇A^T for the
    /// column-order GEGLU backward. ∇Z^T is therefore born transposed:
    /// the second MVUE runs with zero staging, and the old explicit
    /// ∇Z-transpose plus both spMM-internal ∇Y^T/∇Z^T stagings are gone.
    /// Only ∇X converts back to row-major, inside its spMM epilogue.
    pub fn backward_scratch(
        &self,
        x: &Tensor,
        cache: &FfnCache,
        dy: &Tensor,
        rng: &mut Rng,
        g: &mut FfnGrads,
        scratch: &mut Scratch,
    ) {
        match self.mode {
            SparseMode::Weight => {
                self.backward_weight(x, cache, dy, rng, g, scratch, false)
            }
            SparseMode::Both => {
                self.backward_weight(x, cache, dy, rng, g, scratch, true)
            }
            SparseMode::Activation => {
                self.backward_activation(x, cache, dy, g, scratch)
            }
        }
    }

    /// The FST backward (`Weight`, and with `ste_mask` the `Both`
    /// variant, which additionally zeroes ∇A^T on the pruned activation
    /// lanes before the GEGLU backward — straight-through through the
    /// activation pruning; the MVUE weight-grad spMM already consumes
    /// the PRUNED A^T from the cache, which is exactly the STE ∇W2).
    fn backward_weight(
        &self,
        x: &Tensor,
        cache: &FfnCache,
        dy: &Tensor,
        rng: &mut Rng,
        g: &mut FfnGrads,
        scratch: &mut Scratch,
        ste_mask: bool,
    ) {
        let (p, d) = dy.dims2();
        let (_, r) = self.dense.w2.dims2();
        let (two_r, _) = self.dense.w1.dims2();
        let mut uni = scratch.take_vec(0);
        let mut gcomp = scratch.take_comp();
        // Distinct MVUE buffers per shape so their lengths never change
        // across steps (resize_to's zero-fill only triggers on a length
        // change — reusing one buffer for both shapes would memset
        // 2*(2r*p) dead floats per step).
        // ∇W2 = MVUE(∇Y^T) A — A^T is consumed in place (gather-dot)
        let mut gt_dy = scratch.take(&[d, p]);
        let mut mv_dy = scratch.take(&[d, p]);
        kernels::transpose(dy, &mut gt_dy);
        mvue24_into(&gt_dy, rng, &mut uni, &mut mv_dy);
        compress_sparse24_into(&mv_dy, &mut gcomp);
        g.dw2.resize_to(&self.dense.w2.shape);
        kernels::spmm_tn_cm_into(&gcomp, &cache.a, &mut g.dw2);
        col_sum_into(dy, &mut g.db2);
        // ∇A^T = (∇Y (W2 ⊙ M2))^T — via the compressed transpose
        // (Eq. 5), streaming the ∇Y^T we already have
        let mut da = scratch.take(&[r, p]);
        kernels::spmm_nt_tcm_into(&gt_dy, &self.w2ct, &mut da);
        if ste_mask {
            apply_act_mask(&mut da, &cache.act_mask);
        }
        let mut dz = scratch.take(&[two_r, p]);
        geglu_cm_grad_into(&cache.z, &da, &mut dz);
        // ∇W1 = MVUE(∇Z^T) X — dz IS ∇Z^T already; x is row-major
        let mut mv_dz = scratch.take(&[two_r, p]);
        mvue24_into(&dz, rng, &mut uni, &mut mv_dz);
        compress_sparse24_into(&mv_dz, &mut gcomp);
        g.dw1.resize_to(&self.dense.w1.shape);
        spmm_tn_into(&gcomp, x, &mut g.dw1);
        row_sum_into(&dz, &mut g.db1);
        // ∇X = ∇Z (W1 ⊙ M1) — ∇Z^T streams, the epilogue scatters back
        // to row-major at the block boundary
        g.dx.resize_to(&x.shape);
        kernels::spmm_nt_t_into(&dz, &self.w1ct, &mut g.dx);
        scratch.give(gt_dy);
        scratch.give(mv_dy);
        scratch.give(mv_dz);
        scratch.give(da);
        scratch.give(dz);
        scratch.give_vec(uni);
        scratch.give_comp(gcomp);
    }

    /// Straight-through backward for `Activation` mode. The weights are
    /// dense, so there is no MVUE estimator and no compressed-transpose
    /// machinery — the only sparsity effect is the keep-mask recorded by
    /// the forward: ∇W2 reads the PRUNED A^T from the cache, and ∇A^T
    /// is masked to the surviving lanes before the GEGLU backward. Same
    /// column-major interior as the FST path: ∇Y is transposed ONCE and
    /// that ∇Y^T feeds both the ∇W2 GEMM and the ∇A^T GEMM.
    fn backward_activation(
        &self,
        x: &Tensor,
        cache: &FfnCache,
        dy: &Tensor,
        g: &mut FfnGrads,
        scratch: &mut Scratch,
    ) {
        let (p, d) = dy.dims2();
        let (_, r) = self.dense.w2.dims2();
        let (two_r, _) = self.dense.w1.dims2();
        let mut gt_dy = scratch.take(&[d, p]);
        kernels::transpose(dy, &mut gt_dy);
        // ∇W2 = ∇Y^T Â — cache.a holds the pruned Â^T
        g.dw2.resize_to(&self.dense.w2.shape);
        kernels::gemm_nt_into(&gt_dy, &cache.a, &mut g.dw2);
        col_sum_into(dy, &mut g.db2);
        // ∇Â^T = W2^T ∇Y^T, then straight-through: only survivors flow
        let mut da = scratch.take(&[r, p]);
        kernels::gemm_tn_into(&self.dense.w2, &gt_dy, &mut da);
        apply_act_mask(&mut da, &cache.act_mask);
        let mut dz = scratch.take(&[two_r, p]);
        geglu_cm_grad_into(&cache.z, &da, &mut dz);
        // ∇W1 = ∇Z^T X; ∇X = ∇Z W1 (dz IS ∇Z^T)
        g.dw1.resize_to(&self.dense.w1.shape);
        kernels::gemm_nn_into(&dz, x, &mut g.dw1);
        row_sum_into(&dz, &mut g.db1);
        g.dx.resize_to(&x.shape);
        kernels::gemm_tn_into(&dz, &self.dense.w1, &mut g.dx);
        scratch.give(gt_dy);
        scratch.give(da);
        scratch.give(dz);
    }
}

/// Zero ∇A^T on the lanes the forward pruned away (straight-through
/// estimator). `mask` is the keep-byte vector [`prune_act24_cm`] wrote,
/// in the same A^T (r, p) layout as `da`.
fn apply_act_mask(da: &mut Tensor, mask: &[u8]) {
    assert_eq!(
        da.len(),
        mask.len(),
        "activation mask is stale: backward shape != forward shape"
    );
    for (v, &keep) in da.data.iter_mut().zip(mask) {
        if keep == 0 {
            *v = 0.0;
        }
    }
}

/// 2:4-prune a column-major activation block in place and (optionally)
/// record the keep-mask and pack the survivors for the
/// compressed-stationary second matmul.
///
/// `at` is A^T (r, p): token i lives in column i, and each group of
/// four consecutive hidden lanes (rows 4g..4g+4) keeps its top-2
/// magnitude pair with [`top2_of4`]'s deterministic tie-breaking —
/// groups run along the hidden dimension, so they are the SAME logical
/// groups `prune24_mask` would form on the row-major A (p, r). Pruned
/// lanes are zeroed in place (the `Both` pipeline streams the zeroed
/// A^T through the weight-compressed spMM). `mask`, when given, gets
/// one keep-byte per A^T element (same (r, p) layout — the
/// straight-through backward applies it to ∇A^T directly). `comp`,
/// when given, is reset to the ROW-major compressed activation A
/// (rows = p tokens, cols = r lanes) that
/// [`crate::sparse::kernels::spmm_tn_cm_into`] consumes stationary;
/// the in-group packing order (ascending lane index) matches
/// [`Compressed24::from_masked_into`] exactly.
///
/// Sequential and deterministic: the output bytes depend only on `at`,
/// never on thread count or call history.
pub fn prune_act24_cm(
    at: &mut Tensor,
    mask: Option<&mut Vec<u8>>,
    comp: Option<&mut Compressed24>,
) {
    let (r, p) = at.dims2();
    assert_eq!(r % 4, 0, "activation rows {r} not a multiple of 4");
    let half = r / 2;
    let mut mask = match mask {
        Some(m) => {
            m.clear();
            m.resize(r * p, 0);
            Some(m)
        }
        None => None,
    };
    let mut comp = match comp {
        Some(c) => {
            c.reset(p, r);
            Some(c)
        }
        None => None,
    };
    let mut g4 = [0f32; 4];
    for g in 0..r / 4 {
        let base = 4 * g * p;
        for i in 0..p {
            for (k, v) in g4.iter_mut().enumerate() {
                *v = at.data[base + k * p + i];
            }
            let (k0, k1) = top2_of4(&g4);
            for k in 0..4 {
                if k != k0 && k != k1 {
                    at.data[base + k * p + i] = 0.0;
                }
            }
            if let Some(m) = mask.as_mut() {
                m[(4 * g + k0) * p + i] = 1;
                m[(4 * g + k1) * p + i] = 1;
            }
            if let Some(c) = comp.as_mut() {
                let o = i * half + g * 2;
                c.values[o] = g4[k0];
                c.values[o + 1] = g4[k1];
                c.indices[o] = k0 as u8;
                c.indices[o + 1] = k1 as u8;
                c.abs_indices[o] = (4 * g + k0) as u32;
                c.abs_indices[o + 1] = (4 * g + k1) as u32;
            }
        }
    }
}

/// Inference-only FFN.
///
/// This is the serving counterpart of [`SparseFfn`]. In `Weight` mode
/// (the default) weights live EXCLUSIVELY in compressed 2:4 form: no
/// dense master weights, no masks, no transposed copies for the
/// backward pass — just the two compressed operands the forward spMMs
/// consume, at half the dense footprint (plus 2-bit metadata). In
/// `Activation` mode the weights stay dense and the 2:4 operand is
/// built per batch from the live activations. Built once from a
/// trained checkpoint (or a live [`SparseFfn`]) and then immutable.
#[derive(Clone, Debug)]
pub struct FrozenFfn {
    pub mode: SparseMode,
    pub w1c: Compressed24,
    pub b1: Tensor,
    pub w2c: Compressed24,
    pub b2: Tensor,
    /// Dense weights, held ONLY when `mode` prunes no weights
    /// (`Activation`): W1 (2r, d) and W2 (d, r).
    pub w1d: Option<Tensor>,
    pub w2d: Option<Tensor>,
}

impl FrozenFfn {
    /// Compress dense weights under their 2:4 masks (checkpoint loading).
    pub fn from_masked(w1: &Tensor, m1: &Mask, b1: Tensor,
                       w2: &Tensor, m2: &Mask, b2: Tensor) -> FrozenFfn {
        FrozenFfn {
            mode: SparseMode::Weight,
            w1c: Compressed24::from_masked(w1, m1),
            b1,
            w2c: Compressed24::from_masked(w2, m2),
            b2,
            w1d: None,
            w2d: None,
        }
    }

    /// Weight-compressed operands PLUS per-batch activation pruning
    /// (`Both` serving mode).
    pub fn from_masked_both(w1: &Tensor, m1: &Mask, b1: Tensor,
                            w2: &Tensor, m2: &Mask, b2: Tensor) -> FrozenFfn {
        let mut f = Self::from_masked(w1, m1, b1, w2, m2, b2);
        f.mode = SparseMode::Both;
        f
    }

    /// Dense weights, 2:4-pruned activations (`Activation` serving
    /// mode): no masks, no compression — the sparse operand is built
    /// per batch inside [`FrozenFfn::forward_into`].
    pub fn from_dense(w1: Tensor, b1: Tensor, w2: Tensor, b2: Tensor) -> FrozenFfn {
        FrozenFfn {
            mode: SparseMode::Activation,
            w1c: Compressed24::default(),
            b1,
            w2c: Compressed24::default(),
            b2,
            w1d: Some(w1),
            w2d: Some(w2),
        }
    }

    /// Freeze a training-time [`SparseFfn`] (drops everything backward
    /// needs, keeps the forward operands). Honors `sf.mode`.
    pub fn from_sparse(sf: &SparseFfn) -> FrozenFfn {
        if !sf.mode.sparse_weights() {
            let mut f = FrozenFfn::from_dense(
                sf.dense.w1.clone(),
                sf.dense.b1.clone(),
                sf.dense.w2.clone(),
                sf.dense.b2.clone(),
            );
            f.mode = sf.mode;
            f
        } else {
            FrozenFfn {
                mode: sf.mode,
                w1c: sf.w1c.clone(),
                b1: sf.dense.b1.clone(),
                w2c: sf.w2c.clone(),
                b2: sf.dense.b2.clone(),
                w1d: None,
                w2d: None,
            }
        }
    }

    /// (d_model, d_ff) this FFN was built for.
    pub fn dims(&self) -> (usize, usize) {
        if self.mode.sparse_weights() {
            (self.w1c.cols, self.w2c.cols)
        } else {
            let w1 = self.w1d.as_ref().expect("activation-mode FFN lost its dense W1");
            let w2 = self.w2d.as_ref().expect("activation-mode FFN lost its dense W2");
            (w1.dims2().1, w2.dims2().1)
        }
    }

    /// Inference forward. Identical arithmetic to
    /// [`SparseFfn::forward_scratch`] in the same mode — including its
    /// column-major Table-12 interior (Z^T and A^T temporaries, fused
    /// layout conversion in the matmul epilogues; `Activation` packs
    /// the pruned activation into a scratch-pooled [`Compressed24`]) —
    /// but every temporary comes from `scratch` and nothing is cached;
    /// decode steps in the steady state allocate nothing.
    pub fn forward_into(&self, x: &Tensor, y: &mut Tensor, scratch: &mut Scratch) {
        let (p, _) = x.dims2();
        match self.mode {
            SparseMode::Weight => {
                let mut z = scratch.take(&[self.w1c.rows, p]);
                kernels::spmm_nt_cm_into(x, &self.w1c, &mut z);
                add_bias_cm(&mut z, &self.b1);
                let mut a = scratch.take(&[self.w1c.rows / 2, p]);
                geglu_cm_into(&z, &mut a);
                y.resize_to(&[p, self.w2c.rows]);
                kernels::spmm_nt_t_into(&a, &self.w2c, y);
                add_bias(y, &self.b2);
                scratch.give(z);
                scratch.give(a);
            }
            SparseMode::Activation => {
                let w1 = self.w1d.as_ref().expect("activation-mode FFN lost its dense W1");
                let w2 = self.w2d.as_ref().expect("activation-mode FFN lost its dense W2");
                let (two_r, _) = w1.dims2();
                let (d, _) = w2.dims2();
                let mut z = scratch.take(&[two_r, p]);
                kernels::gemm_nt_into(w1, x, &mut z);
                add_bias_cm(&mut z, &self.b1);
                let mut a = scratch.take(&[two_r / 2, p]);
                geglu_cm_into(&z, &mut a);
                let mut acomp = scratch.take_comp();
                prune_act24_cm(&mut a, None, Some(&mut acomp));
                y.resize_to(&[p, d]);
                kernels::spmm_tn_cm_into(&acomp, w2, y);
                add_bias(y, &self.b2);
                scratch.give_comp(acomp);
                scratch.give(z);
                scratch.give(a);
            }
            SparseMode::Both => {
                let mut z = scratch.take(&[self.w1c.rows, p]);
                kernels::spmm_nt_cm_into(x, &self.w1c, &mut z);
                add_bias_cm(&mut z, &self.b1);
                let mut a = scratch.take(&[self.w1c.rows / 2, p]);
                geglu_cm_into(&z, &mut a);
                prune_act24_cm(&mut a, None, None);
                y.resize_to(&[p, self.w2c.rows]);
                kernels::spmm_nt_t_into(&a, &self.w2c, y);
                add_bias(y, &self.b2);
                scratch.give(z);
                scratch.give(a);
            }
        }
    }
}

/// Compress a tensor that is ALREADY <=2-nonzero per group of four (e.g.
/// an MVUE output) without re-ranking magnitudes.
pub fn compress_sparse24(t: &Tensor) -> Compressed24 {
    let mut out = Compressed24::default();
    compress_sparse24_into(t, &mut out);
    out
}

/// In-place variant reusing `out`'s buffers (zero-allocation hot path).
pub fn compress_sparse24_into(t: &Tensor, out: &mut Compressed24) {
    let (r, c) = t.dims2();
    assert_eq!(c % 4, 0);
    let half = c / 2;
    out.reset(r, c);
    let (values, indices, abs_indices) =
        (&mut out.values, &mut out.indices, &mut out.abs_indices);
    for i in 0..r {
        let mut o = i * half;
        for g in 0..c / 4 {
            let base = i * c + g * 4;
            let mut taken = 0;
            for k in 0..4 {
                let v = t.data[base + k];
                if v != 0.0 && taken < 2 {
                    values[o] = v;
                    indices[o] = k as u8;
                    abs_indices[o] = (g * 4 + k) as u32;
                    o += 1;
                    taken += 1;
                }
            }
            // pad with explicit zeros at distinct positions
            let mut k = 0;
            while taken < 2 {
                if !indices[i * half + g * 2..o].contains(&(k as u8)) || o == i * half + g * 2 {
                    values[o] = 0.0;
                    indices[o] = k as u8;
                    abs_indices[o] = (g * 4 + k) as u32;
                    o += 1;
                    taken += 1;
                }
                k += 1;
            }
        }
    }
}

pub fn add_bias(x: &mut Tensor, b: &Tensor) {
    let (p, c) = x.dims2();
    assert_eq!(b.len(), c);
    for i in 0..p {
        for j in 0..c {
            x.data[i * c + j] += b.data[j];
        }
    }
}

/// [`add_bias`] for a COLUMN-major activation: `x` is X^T (c, p), so
/// feature j's bias sweeps one contiguous row — the Table-12 layout
/// makes the bias add a streaming pass instead of a strided one.
pub fn add_bias_cm(x: &mut Tensor, b: &Tensor) {
    let (c, p) = x.dims2();
    assert_eq!(b.len(), c);
    for j in 0..c {
        let bj = b.data[j];
        for v in &mut x.data[j * p..(j + 1) * p] {
            *v += bj;
        }
    }
}

pub fn col_sum(x: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[0]);
    col_sum_into(x, &mut out);
    out
}

pub fn col_sum_into(x: &Tensor, out: &mut Tensor) {
    let (p, c) = x.dims2();
    out.resize_to(&[c]);
    out.data.fill(0.0);
    for i in 0..p {
        for j in 0..c {
            out.data[j] += x.data[i * c + j];
        }
    }
}

/// Per-feature sum of a COLUMN-major activation: `x` is X^T (c, p), so
/// [`col_sum_into`]'s strided token loop becomes one contiguous pass per
/// feature. Accumulation order per feature (token-ascending) is
/// identical, so the bias gradients match the row-major path bitwise.
pub fn row_sum_into(x: &Tensor, out: &mut Tensor) {
    let (c, p) = x.dims2();
    out.resize_to(&[c]);
    for j in 0..c {
        let mut s = 0f32;
        for &v in &x.data[j * p..(j + 1) * p] {
            s += v;
        }
        out.data[j] = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::mvue::mvue24;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        Tensor::normal(shape, 0.5, &mut Rng::new(seed))
    }

    #[test]
    fn sparse_forward_equals_dense_on_masked_weights() {
        let mut rng = Rng::new(0);
        let sf = SparseFfn::new(16, 8, &mut rng);
        let mut df = sf.dense.clone();
        df.w1 = sf.m1.apply(&df.w1);
        df.w2 = sf.m2.apply(&df.w2);
        let x = rand(&[12, 16], 1);
        let (ys, _) = sf.forward(&x);
        let (yd, _) = df.forward(&x);
        assert!(ys.max_abs_diff(&yd) < 1e-4);
    }

    #[test]
    fn dense_backward_matches_finite_difference() {
        let mut rng = Rng::new(2);
        let f = DenseFfn::new(8, 4, &mut rng);
        let x = rand(&[4, 8], 3);
        let (y, cache) = f.forward(&x);
        let dy = Tensor::ones(&[4, 8]);
        let g = f.backward(&x, &cache, &dy);
        let h = 1e-3f32;
        // check a few dw1 entries by central differences on sum(y)
        for &k in &[0usize, 5, 17, 33] {
            let mut fp = f.clone();
            fp.w1.data[k] += h;
            let mut fm = f.clone();
            fm.w1.data[k] -= h;
            let fd = ((fp.forward(&x).0.sum() - fm.forward(&x).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((g.dw1.data[k] - fd).abs() < 3e-2,
                    "k={k}: {} vs {fd}", g.dw1.data[k]);
        }
        // dx entry
        for &k in &[0usize, 9] {
            let mut xp = x.clone();
            xp.data[k] += h;
            let mut xm = x.clone();
            xm.data[k] -= h;
            let fd = ((f.forward(&xp).0.sum() - f.forward(&xm).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((g.dx.data[k] - fd).abs() < 3e-2);
        }
        assert_eq!(y.shape, vec![4, 8]);
    }

    #[test]
    fn sparse_backward_input_grad_matches_masked_dense() {
        // With MVUE replaced by its mean (we verify dx only, which has no
        // MVUE noise), sparse dx == dense-on-masked-weights dx.
        let mut rng = Rng::new(4);
        let sf = SparseFfn::new(16, 8, &mut rng);
        let mut df = sf.dense.clone();
        df.w1 = sf.m1.apply(&df.w1);
        df.w2 = sf.m2.apply(&df.w2);
        let x = rand(&[8, 16], 5);
        let (_, cs) = sf.forward(&x);
        let (_, cd) = df.forward(&x);
        let dy = rand(&[8, 16], 6);
        let gs = sf.backward(&x, &cs, &dy, &mut Rng::new(7));
        let gd = df.backward(&x, &cd, &dy);
        assert!(gs.dx.max_abs_diff(&gd.dx) < 1e-3);
        assert!(gs.db1.max_abs_diff(&gd.db1) < 1e-3);
        assert!(gs.db2.max_abs_diff(&gd.db2) < 1e-3);
    }

    #[test]
    fn sparse_weight_grads_unbiased() {
        // E[sparse dw2] == dense-masked dw2 over MVUE draws
        let mut rng = Rng::new(8);
        let sf = SparseFfn::new(8, 4, &mut rng);
        let mut df = sf.dense.clone();
        df.w1 = sf.m1.apply(&df.w1);
        df.w2 = sf.m2.apply(&df.w2);
        let x = rand(&[8, 8], 9);
        let (_, cs) = sf.forward(&x);
        let (_, cd) = df.forward(&x);
        let dy = rand(&[8, 8], 10);
        let gd = df.backward(&x, &cd, &dy);
        let mut acc = Tensor::zeros(&gd.dw2.shape);
        let n = 600;
        let mut mrng = Rng::new(11);
        for _ in 0..n {
            let gs = sf.backward(&x, &cs, &dy, &mut mrng);
            for (a, b) in acc.data.iter_mut().zip(&gs.dw2.data) {
                *a += b / n as f32;
            }
        }
        // statistical tolerance
        let denom = gd.dw2.abs_sum().max(1.0) / gd.dw2.len() as f64;
        let err = acc.max_abs_diff(&gd.dw2) as f64;
        assert!(err < 12.0 * denom.max(0.05), "err={err} denom={denom}");
    }

    #[test]
    fn recompress_tracks_weight_updates() {
        let mut rng = Rng::new(12);
        let mut sf = SparseFfn::new(8, 4, &mut rng);
        for v in sf.dense.w1.data.iter_mut() {
            *v += 0.1;
        }
        let before = sf.w1c.values.clone();
        sf.recompress();
        assert_ne!(before, sf.w1c.values);
        // masks unchanged by recompress
        assert!(sf.m1.is_transposable());
        // compressed transposes track the update too
        assert_eq!(sf.w1ct.to_dense(), sf.m1t.apply(&sf.dense.w1.t()));
    }

    #[test]
    fn frozen_ffn_matches_sparse_forward_and_stops_allocating() {
        let mut rng = Rng::new(20);
        let sf = SparseFfn::new(16, 8, &mut rng);
        let ff = FrozenFfn::from_sparse(&sf);
        assert_eq!(ff.dims(), (16, 8));
        let x = rand(&[8, 16], 21);
        let (y_ref, _) = sf.forward(&x);
        let mut y = Tensor::zeros(&[0]);
        let mut s = Scratch::new();
        ff.forward_into(&x, &mut y, &mut s);
        assert_eq!(y, y_ref);
        let fresh = s.fresh_allocs();
        ff.forward_into(&x, &mut y, &mut s);
        assert_eq!(y, y_ref);
        assert_eq!(s.fresh_allocs(), fresh, "steady-state forward allocated");
        // from_masked agrees with the training-side compression
        let ff2 = FrozenFfn::from_masked(&sf.dense.w1, &sf.m1, sf.dense.b1.clone(),
                                         &sf.dense.w2, &sf.m2, sf.dense.b2.clone());
        let mut y2 = Tensor::zeros(&[0]);
        ff2.forward_into(&x, &mut y2, &mut s);
        assert_eq!(y2, y_ref);
    }

    #[test]
    fn cm_helpers_match_row_major_bitwise() {
        // add_bias_cm / row_sum_into are the column-major twins of
        // add_bias / col_sum_into: same per-element arithmetic and the
        // same token-ascending accumulation order, so transposed inputs
        // must produce bitwise-equal results
        let x = rand(&[7, 10], 30);
        let b = rand(&[10], 31);
        let mut rm = x.clone();
        add_bias(&mut rm, &b);
        let mut cm = x.t();
        add_bias_cm(&mut cm, &b);
        assert_eq!(cm, rm.t());
        let mut s_rm = Tensor::zeros(&[0]);
        col_sum_into(&x, &mut s_rm);
        let mut s_cm = Tensor::zeros(&[0]);
        row_sum_into(&x.t(), &mut s_cm);
        assert_eq!(s_cm, s_rm);
    }

    #[test]
    fn prune_act24_cm_packs_like_row_major_compression() {
        // column-wise pruning of A^T picks the same lanes as the
        // row-major weight-path pruner on A (same logical groups of 4
        // along the hidden dim), and the packed operand round-trips
        let a = rand(&[6, 8], 40); // A (p=6, r=8)
        let mut at = a.t();
        let mut mask = Vec::new();
        let mut comp = Compressed24::default();
        prune_act24_cm(&mut at, Some(&mut mask), Some(&mut comp));
        let m = crate::sparse::mask::prune24_mask(&a);
        let pruned = m.apply(&a);
        assert_eq!(at, pruned.t());
        assert_eq!(comp.to_dense(), pruned);
        // keep-mask bytes are the transposed weight-path mask
        for lane in 0..8 {
            for tok in 0..6 {
                assert_eq!(mask[lane * 6 + tok], m.at(tok, lane));
            }
        }
    }

    #[test]
    fn activation_mode_forward_matches_masked_dense_oracle() {
        let mut rng = Rng::new(50);
        let sf = SparseFfn::new_with_mode(16, 8, SparseMode::Activation, &mut rng);
        let x = rand(&[6, 16], 51);
        let (y, cache) = sf.forward(&x);
        // replay the pipeline prefix with public kernels to get the
        // unpruned A^T, then prune row-major and finish with a dense GEMM
        let mut z = Tensor::zeros(&[16, 6]);
        kernels::gemm_nt_into(&sf.dense.w1, &x, &mut z);
        add_bias_cm(&mut z, &sf.dense.b1);
        let mut at = Tensor::zeros(&[0]);
        geglu_cm_into(&z, &mut at);
        let a = at.t();
        let ap = crate::sparse::mask::prune24_mask(&a).apply(&a);
        let mut y_ref = Tensor::zeros(&[6, 16]);
        gemm_nt_into(&ap, &sf.dense.w2, &mut y_ref);
        add_bias(&mut y_ref, &sf.dense.b2);
        assert!(y.max_abs_diff(&y_ref) < 1e-5, "{}", y.max_abs_diff(&y_ref));
        // the cached pruned A^T and packed operand agree with the oracle
        assert_eq!(cache.a, ap.t());
        assert_eq!(cache.acomp.to_dense(), ap);
    }

    #[test]
    fn compress_sparse24_roundtrip() {
        let mut rng = Rng::new(13);
        let x = Tensor::normal(&[4, 16], 1.0, &mut rng);
        let s = mvue24(&x, &mut rng);
        let c = compress_sparse24(&s);
        assert!(c.to_dense().max_abs_diff(&s) < 1e-6);
    }

    #[test]
    fn scratch_paths_match_allocating_paths() {
        let mut rng = Rng::new(14);
        let sf = SparseFfn::new(16, 8, &mut rng);
        let x = rand(&[8, 16], 15);
        let dy = rand(&[8, 16], 16);
        // allocating reference
        let (y_ref, cache_ref) = sf.forward(&x);
        let g_ref = sf.backward(&x, &cache_ref, &dy, &mut Rng::new(17));
        // scratch path, run twice to exercise buffer reuse
        let mut cache = FfnCache::empty();
        let mut y = Tensor::zeros(&[0]);
        let mut g = FfnGrads::empty();
        let mut s = Scratch::new();
        for _ in 0..2 {
            sf.forward_scratch(&x, &mut cache, &mut y);
            sf.backward_scratch(&x, &cache, &dy, &mut Rng::new(17), &mut g, &mut s);
        }
        assert_eq!(y, y_ref);
        assert_eq!(g.dx, g_ref.dx);
        assert_eq!(g.dw1, g_ref.dw1);
        assert_eq!(g.dw2, g_ref.dw2);
        assert_eq!(g.db1, g_ref.db1);
        assert_eq!(g.db2, g_ref.db2);
        // steady state: the arena stops growing after the first iteration
        let pooled = s.pooled();
        sf.forward_scratch(&x, &mut cache, &mut y);
        let mut g2 = FfnGrads::empty();
        sf.backward_scratch(&x, &cache, &dy, &mut Rng::new(17), &mut g2, &mut s);
        assert_eq!(s.pooled(), pooled);
    }
}
