//! FFN layer on the CPU substrate — dense vs. FST 2:4 (Fig. 7a, Table 13).
//!
//! Implements the paper's full per-iteration FFN workflow (Appendix B):
//!
//!   forward:   Z = X (W1 ⊙ M1)^T + b1;  A = GEGLU(Z);  Y = A (W2 ⊙ M2)^T + b2
//!   backward:  ∇W2 = MVUE(∇Y^T) A        (spmm_tn, Eq. 4+6)
//!              ∇A  = ∇Y (W2 ⊙ M2)        (spmm_nn, Eq. 3)
//!              ∇Z  = GEGLU'(Z) ∘ ∇A
//!              ∇W1 = MVUE(∇Z^T) X
//!              ∇X  = ∇Z (W1 ⊙ M1)
//!
//! plus the per-step weight (re)compression and the every-l-steps
//! transposable-mask search. The dense twin runs the same shapes through
//! dense GEMMs. Numerical equivalence between the two forwards under an
//! all-kept comparison is tested below; the speed comparison is the
//! Fig. 7a bench.

use super::gemm::{gemm_nn, gemm_nt, gemm_tn};
use super::geglu::{geglu_row_major, geglu_row_major_grad};
use super::mask::Mask;
use super::mvue::mvue24;
use super::spmm::{spmm_nt, spmm_tn, Compressed24};
use super::transposable::transposable_mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Gradients of one FFN layer.
#[derive(Debug)]
pub struct FfnGrads {
    pub dx: Tensor,
    pub dw1: Tensor,
    pub db1: Tensor,
    pub dw2: Tensor,
    pub db2: Tensor,
}

/// Dense FFN layer: W1 (2r, d), W2 (d, r), gated activation.
#[derive(Clone, Debug)]
pub struct DenseFfn {
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
}

/// Forward cache reused by the backward pass.
pub struct FfnCache {
    pub z: Tensor,
    pub a: Tensor,
}

impl DenseFfn {
    pub fn new(d: usize, r: usize, rng: &mut Rng) -> Self {
        DenseFfn {
            w1: Tensor::normal(&[2 * r, d], 0.02, rng),
            b1: Tensor::zeros(&[2 * r]),
            w2: Tensor::normal(&[d, r], 0.02, rng),
            b2: Tensor::zeros(&[d]),
        }
    }

    pub fn forward(&self, x: &Tensor) -> (Tensor, FfnCache) {
        let mut z = gemm_nt(x, &self.w1);
        add_bias(&mut z, &self.b1);
        let a = geglu_row_major(&z);
        let mut y = gemm_nt(&a, &self.w2);
        add_bias(&mut y, &self.b2);
        (y, FfnCache { z, a })
    }

    pub fn backward(&self, x: &Tensor, cache: &FfnCache, dy: &Tensor) -> FfnGrads {
        let dw2 = gemm_tn(dy, &cache.a);
        let db2 = col_sum(dy);
        let da = gemm_nn(dy, &self.w2);
        let dz = geglu_row_major_grad(&cache.z, &da);
        let dw1 = gemm_tn(&dz, x);
        let db1 = col_sum(&dz);
        let dx = gemm_nn(&dz, &self.w1);
        FfnGrads { dx, dw1, db1, dw2, db2 }
    }
}

/// FST 2:4 FFN layer: dense master weights + transposable masks +
/// compressed operands, refreshed per the paper's schedule.
#[derive(Clone, Debug)]
pub struct SparseFfn {
    pub dense: DenseFfn,
    pub m1: Mask,
    pub m2: Mask,
    pub w1c: Compressed24,
    pub w2c: Compressed24,
    /// compressed TRANSPOSES — the transposable masks (Eq. 5) guarantee
    /// W^T ⊙ M^T is also row-wise 2:4, so the backward input-grad GEMM
    /// (Eq. 3) runs through the same fast spmm_nt kernel. This is exactly
    /// the property the paper's transposable-mask machinery buys.
    pub w1ct: Compressed24,
    pub w2ct: Compressed24,
}

impl SparseFfn {
    pub fn new(d: usize, r: usize, rng: &mut Rng) -> Self {
        let dense = DenseFfn::new(d, r, rng);
        let m1 = transposable_mask(&dense.w1);
        let m2 = transposable_mask(&dense.w2);
        let w1c = Compressed24::from_masked(&dense.w1, &m1);
        let w2c = Compressed24::from_masked(&dense.w2, &m2);
        let w1ct = Compressed24::from_masked(&dense.w1.t(), &m1.transpose());
        let w2ct = Compressed24::from_masked(&dense.w2.t(), &m2.transpose());
        SparseFfn { dense, m1, m2, w1c, w2c, w1ct, w2ct }
    }

    /// Per-step "prune weights": recompress values under the CURRENT masks
    /// (cheap; Table 13's `Prune weights` row).
    pub fn recompress(&mut self) {
        self.w1c = Compressed24::from_masked(&self.dense.w1, &self.m1);
        self.w2c = Compressed24::from_masked(&self.dense.w2, &self.m2);
        self.w1ct = Compressed24::from_masked(&self.dense.w1.t(), &self.m1.transpose());
        self.w2ct = Compressed24::from_masked(&self.dense.w2.t(), &self.m2.transpose());
    }

    /// Every-l-steps transposable mask search (Table 13's bottom row).
    pub fn refresh_masks(&mut self) {
        self.m1 = transposable_mask(&self.dense.w1);
        self.m2 = transposable_mask(&self.dense.w2);
        self.recompress();
    }

    pub fn forward(&self, x: &Tensor) -> (Tensor, FfnCache) {
        let mut z = spmm_nt(x, &self.w1c);
        add_bias(&mut z, &self.dense.b1);
        let a = geglu_row_major(&z);
        let mut y = spmm_nt(&a, &self.w2c);
        add_bias(&mut y, &self.dense.b2);
        (y, FfnCache { z, a })
    }

    /// FST backward: MVUE-compressed gradient spMMs (Eq. 4+6) and
    /// masked-weight input-grad spMMs (Eq. 3).
    pub fn backward(&self, x: &Tensor, cache: &FfnCache, dy: &Tensor,
                    rng: &mut Rng) -> FfnGrads {
        // ∇W2 = MVUE(∇Y^T) A
        let dyt_s = mvue24(&dy.t(), rng);
        let dw2 = spmm_tn(&compress_sparse24(&dyt_s), &cache.a);
        let db2 = col_sum(dy);
        // ∇A = ∇Y (W2 ⊙ M2) — via the compressed transpose (Eq. 5)
        let da = spmm_nt(dy, &self.w2ct);
        let dz = geglu_row_major_grad(&cache.z, &da);
        // ∇W1 = MVUE(∇Z^T) X
        let dzt_s = mvue24(&dz.t(), rng);
        let dw1 = spmm_tn(&compress_sparse24(&dzt_s), x);
        let db1 = col_sum(&dz);
        // ∇X = ∇Z (W1 ⊙ M1) — via the compressed transpose
        let dx = spmm_nt(&dz, &self.w1ct);
        FfnGrads { dx, dw1, db1, dw2, db2 }
    }
}

/// Compress a tensor that is ALREADY <=2-nonzero per group of four (e.g.
/// an MVUE output) without re-ranking magnitudes.
pub fn compress_sparse24(t: &Tensor) -> Compressed24 {
    let (r, c) = t.dims2();
    assert_eq!(c % 4, 0);
    let half = c / 2;
    let mut values = vec![0f32; r * half];
    let mut indices = vec![0u8; r * half];
    let mut abs_indices = vec![0u32; r * half];
    for i in 0..r {
        let mut o = i * half;
        for g in 0..c / 4 {
            let base = i * c + g * 4;
            let mut taken = 0;
            for k in 0..4 {
                let v = t.data[base + k];
                if v != 0.0 && taken < 2 {
                    values[o] = v;
                    indices[o] = k as u8;
                    abs_indices[o] = (g * 4 + k) as u32;
                    o += 1;
                    taken += 1;
                }
            }
            // pad with explicit zeros at distinct positions
            let mut k = 0;
            while taken < 2 {
                if !indices[i * half + g * 2..o].contains(&(k as u8)) || o == i * half + g * 2 {
                    values[o] = 0.0;
                    indices[o] = k as u8;
                    abs_indices[o] = (g * 4 + k) as u32;
                    o += 1;
                    taken += 1;
                }
                k += 1;
            }
        }
    }
    Compressed24 { rows: r, cols: c, values, indices, abs_indices }
}

pub fn add_bias(x: &mut Tensor, b: &Tensor) {
    let (p, c) = x.dims2();
    assert_eq!(b.len(), c);
    for i in 0..p {
        for j in 0..c {
            x.data[i * c + j] += b.data[j];
        }
    }
}

pub fn col_sum(x: &Tensor) -> Tensor {
    let (p, c) = x.dims2();
    let mut out = Tensor::zeros(&[c]);
    for i in 0..p {
        for j in 0..c {
            out.data[j] += x.data[i * c + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        Tensor::normal(shape, 0.5, &mut Rng::new(seed))
    }

    #[test]
    fn sparse_forward_equals_dense_on_masked_weights() {
        let mut rng = Rng::new(0);
        let sf = SparseFfn::new(16, 8, &mut rng);
        let mut df = sf.dense.clone();
        df.w1 = sf.m1.apply(&df.w1);
        df.w2 = sf.m2.apply(&df.w2);
        let x = rand(&[12, 16], 1);
        let (ys, _) = sf.forward(&x);
        let (yd, _) = df.forward(&x);
        assert!(ys.max_abs_diff(&yd) < 1e-4);
    }

    #[test]
    fn dense_backward_matches_finite_difference() {
        let mut rng = Rng::new(2);
        let f = DenseFfn::new(8, 4, &mut rng);
        let x = rand(&[4, 8], 3);
        let (y, cache) = f.forward(&x);
        let dy = Tensor::ones(&[4, 8]);
        let g = f.backward(&x, &cache, &dy);
        let h = 1e-3f32;
        // check a few dw1 entries by central differences on sum(y)
        for &k in &[0usize, 5, 17, 33] {
            let mut fp = f.clone();
            fp.w1.data[k] += h;
            let mut fm = f.clone();
            fm.w1.data[k] -= h;
            let fd = ((fp.forward(&x).0.sum() - fm.forward(&x).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((g.dw1.data[k] - fd).abs() < 3e-2,
                    "k={k}: {} vs {fd}", g.dw1.data[k]);
        }
        // dx entry
        for &k in &[0usize, 9] {
            let mut xp = x.clone();
            xp.data[k] += h;
            let mut xm = x.clone();
            xm.data[k] -= h;
            let fd = ((f.forward(&xp).0.sum() - f.forward(&xm).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((g.dx.data[k] - fd).abs() < 3e-2);
        }
        assert_eq!(y.shape, vec![4, 8]);
    }

    #[test]
    fn sparse_backward_input_grad_matches_masked_dense() {
        // With MVUE replaced by its mean (we verify dx only, which has no
        // MVUE noise), sparse dx == dense-on-masked-weights dx.
        let mut rng = Rng::new(4);
        let sf = SparseFfn::new(16, 8, &mut rng);
        let mut df = sf.dense.clone();
        df.w1 = sf.m1.apply(&df.w1);
        df.w2 = sf.m2.apply(&df.w2);
        let x = rand(&[8, 16], 5);
        let (_, cs) = sf.forward(&x);
        let (_, cd) = df.forward(&x);
        let dy = rand(&[8, 16], 6);
        let gs = sf.backward(&x, &cs, &dy, &mut Rng::new(7));
        let gd = df.backward(&x, &cd, &dy);
        assert!(gs.dx.max_abs_diff(&gd.dx) < 1e-3);
        assert!(gs.db1.max_abs_diff(&gd.db1) < 1e-3);
        assert!(gs.db2.max_abs_diff(&gd.db2) < 1e-3);
    }

    #[test]
    fn sparse_weight_grads_unbiased() {
        // E[sparse dw2] == dense-masked dw2 over MVUE draws
        let mut rng = Rng::new(8);
        let sf = SparseFfn::new(8, 4, &mut rng);
        let mut df = sf.dense.clone();
        df.w1 = sf.m1.apply(&df.w1);
        df.w2 = sf.m2.apply(&df.w2);
        let x = rand(&[8, 8], 9);
        let (_, cs) = sf.forward(&x);
        let (_, cd) = df.forward(&x);
        let dy = rand(&[8, 8], 10);
        let gd = df.backward(&x, &cd, &dy);
        let mut acc = Tensor::zeros(&gd.dw2.shape);
        let n = 600;
        let mut mrng = Rng::new(11);
        for _ in 0..n {
            let gs = sf.backward(&x, &cs, &dy, &mut mrng);
            for (a, b) in acc.data.iter_mut().zip(&gs.dw2.data) {
                *a += b / n as f32;
            }
        }
        // statistical tolerance
        let denom = gd.dw2.abs_sum().max(1.0) / gd.dw2.len() as f64;
        let err = acc.max_abs_diff(&gd.dw2) as f64;
        assert!(err < 12.0 * denom.max(0.05), "err={err} denom={denom}");
    }

    #[test]
    fn recompress_tracks_weight_updates() {
        let mut rng = Rng::new(12);
        let mut sf = SparseFfn::new(8, 4, &mut rng);
        for v in sf.dense.w1.data.iter_mut() {
            *v += 0.1;
        }
        let before = sf.w1c.values.clone();
        sf.recompress();
        assert_ne!(before, sf.w1c.values);
        // masks unchanged by recompress
        assert!(sf.m1.is_transposable());
    }

    #[test]
    fn compress_sparse24_roundtrip() {
        let mut rng = Rng::new(13);
        let x = Tensor::normal(&[4, 16], 1.0, &mut rng);
        let s = mvue24(&x, &mut rng);
        let c = compress_sparse24(&s);
        assert!(c.to_dense().max_abs_diff(&s) < 1e-6);
    }
}
