//! 2-approximation transposable-mask baseline (Hubara et al. 2021).
//!
//! The sort-and-pick algorithm the paper's conv search replaces: per 4x4
//! block, visit entries in decreasing |w| and keep one iff its row and
//! column each still have < 2 kept entries; a dead-ended greedy pass (< 8
//! kept) is repaired by snapping to the valid pattern that preserves the
//! most greedy picks (Hubara et al.'s fix-up stage). Its control flow is
//! branch-heavy — the property the paper blames for its poor GPU
//! throughput (Table 3). We keep the branchy structure faithfully (this is
//! the baseline under test, not something to optimize away).

use super::mask::Mask;
use super::transposable::PATTERNS;
use crate::tensor::Tensor;

/// Greedy 2-approximation per 4x4 block.
pub fn transposable_mask_2approx(w: &Tensor) -> Mask {
    let (r, c) = w.dims2();
    assert!(r % 4 == 0 && c % 4 == 0, "shape ({r},{c}) not 4x4-aligned");
    let mut mask = Mask::zeros(r, c);
    // (|w|, position) scratch reused across blocks
    let mut entries: Vec<(f32, usize)> = Vec::with_capacity(16);
    for bi in (0..r).step_by(4) {
        for bj in (0..c).step_by(4) {
            entries.clear();
            for k in 0..4 {
                for l in 0..4 {
                    let v = w.data[(bi + k) * c + (bj + l)].abs();
                    entries.push((v, k * 4 + l));
                }
            }
            // sort descending by magnitude; ties -> lower position (stable)
            entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            let mut row_cnt = [0u8; 4];
            let mut col_cnt = [0u8; 4];
            let mut kept_bits = [0f32; 16];
            let mut kept = 0;
            for &(_, pos) in entries.iter() {
                let (k, l) = (pos / 4, pos % 4);
                if row_cnt[k] < 2 && col_cnt[l] < 2 {
                    row_cnt[k] += 1;
                    col_cnt[l] += 1;
                    kept_bits[pos] = 1.0;
                    kept += 1;
                    if kept == 8 {
                        break;
                    }
                }
            }
            // repair: the greedy pass can dead-end (< 8 kept, remaining
            // rows/cols mutually saturated); snap to the valid pattern
            // preserving the most greedy picks, then by retained |w|
            let mut absb = [0f32; 16];
            let mut maxv = 0f32;
            for k in 0..4 {
                for l in 0..4 {
                    let v = w.data[(bi + k) * c + (bj + l)].abs();
                    absb[k * 4 + l] = v;
                    maxv = maxv.max(v);
                }
            }
            let big = 1.0 + 16.0 * maxv;
            let mut best = 0usize;
            let mut best_score = f32::MIN;
            for (p, pat) in PATTERNS.iter().enumerate() {
                let mut s = 0f32;
                for k in 0..16 {
                    s += pat[k] * (absb[k] + big * kept_bits[k]);
                }
                if s > best_score {
                    best_score = s;
                    best = p;
                }
            }
            let pat = &PATTERNS[best];
            for k in 0..4 {
                for l in 0..4 {
                    mask.data[(bi + k) * c + (bj + l)] = pat[k * 4 + l] as u8;
                }
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::transposable::{retained_l1, transposable_mask};
    use crate::util::rng::Rng;

    #[test]
    fn produces_valid_transposable_masks() {
        let mut rng = Rng::new(0);
        for seed in 0..5u64 {
            let mut r2 = rng.fork(seed);
            let w = Tensor::normal(&[16, 24], 1.0, &mut r2);
            let m = transposable_mask_2approx(&w);
            assert!(m.is_transposable());
        }
    }

    #[test]
    fn within_factor_two_of_optimal() {
        let mut rng = Rng::new(1);
        let w = Tensor::normal(&[32, 32], 1.0, &mut rng);
        let approx = retained_l1(&w, &transposable_mask_2approx(&w));
        let opt = retained_l1(&w, &transposable_mask(&w));
        assert!(approx <= opt + 1e-9, "approx cannot beat optimal");
        assert!(approx >= 0.5 * opt, "2-approximation bound violated");
    }

    #[test]
    fn often_strictly_suboptimal() {
        // the conv search must win on at least some random inputs — that
        // gap is the accuracy argument for Algorithm 1
        let mut rng = Rng::new(2);
        let mut strictly_worse = 0;
        for _ in 0..20 {
            let w = Tensor::normal(&[8, 8], 1.0, &mut rng);
            let a = retained_l1(&w, &transposable_mask_2approx(&w));
            let o = retained_l1(&w, &transposable_mask(&w));
            if a < o - 1e-9 {
                strictly_worse += 1;
            }
        }
        assert!(strictly_worse > 0);
    }

    #[test]
    fn greedy_keeps_exactly_eight_per_block() {
        let mut rng = Rng::new(3);
        let w = Tensor::normal(&[4, 8], 1.0, &mut rng);
        let m = transposable_mask_2approx(&w);
        assert_eq!(m.count_ones(), 16);
    }
}
