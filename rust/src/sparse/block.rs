//! Full transformer block on the CPU substrate (Fig. 7b-d, Table 13).
//!
//! Attention (dense — the paper only sparsifies FFNs) + FST/dense FFN +
//! layer norms, forward AND backward, so the block-speedup benches measure
//! the same op mix as the paper's profile (Appendix D): the FFN GEMMs are
//! the accelerated part, everything else ("Others") is shared.

use super::ffn::{add_bias, col_sum, DenseFfn, FfnCache, FfnGrads, SparseFfn};
use super::gemm::{gemm_nn, gemm_nt, gemm_nt_into, gemm_tn};
use super::kernels::threading::MutPtr;
use super::kernels::{parallel_rows, with_thread_scratch};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// LayerNorm over the last axis; returns (y, mean, rstd) cache.
pub fn layer_norm(x: &Tensor, scale: &Tensor, bias: &Tensor)
                  -> (Tensor, Vec<f32>, Vec<f32>) {
    let (p, c) = x.dims2();
    let mut y = Tensor::zeros(&x.shape);
    let mut means = vec![0f32; p];
    let mut rstds = vec![0f32; p];
    for i in 0..p {
        let row = &x.data[i * c..(i + 1) * c];
        let mu: f32 = row.iter().sum::<f32>() / c as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
        let rstd = 1.0 / (var + 1e-5).sqrt();
        means[i] = mu;
        rstds[i] = rstd;
        let out = &mut y.data[i * c..(i + 1) * c];
        for j in 0..c {
            out[j] = (row[j] - mu) * rstd * scale.data[j] + bias.data[j];
        }
    }
    (y, means, rstds)
}

/// Inference-only LayerNorm: no (mean, rstd) cache, output into a
/// caller-owned buffer. Same arithmetic order as [`layer_norm`].
pub fn layer_norm_into(x: &Tensor, scale: &Tensor, bias: &Tensor, y: &mut Tensor) {
    let (p, c) = x.dims2();
    y.resize_to(&[p, c]);
    for i in 0..p {
        let row = &x.data[i * c..(i + 1) * c];
        let mu: f32 = row.iter().sum::<f32>() / c as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
        let rstd = 1.0 / (var + 1e-5).sqrt();
        let out = &mut y.data[i * c..(i + 1) * c];
        for j in 0..c {
            out[j] = (row[j] - mu) * rstd * scale.data[j] + bias.data[j];
        }
    }
}

/// Backward of layer_norm. Returns (dx, dscale, dbias).
pub fn layer_norm_grad(x: &Tensor, scale: &Tensor, means: &[f32], rstds: &[f32],
                       dy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let (p, c) = x.dims2();
    let mut dx = Tensor::zeros(&x.shape);
    let mut dscale = Tensor::zeros(&scale.shape);
    let mut dbias = Tensor::zeros(&scale.shape);
    for i in 0..p {
        let row = &x.data[i * c..(i + 1) * c];
        let dyr = &dy.data[i * c..(i + 1) * c];
        let (mu, rstd) = (means[i], rstds[i]);
        // xhat = (x - mu) * rstd; dy/dxhat = dy * scale
        let mut sum_dxh = 0f32;
        let mut sum_dxh_xh = 0f32;
        for j in 0..c {
            let xh = (row[j] - mu) * rstd;
            let dxh = dyr[j] * scale.data[j];
            sum_dxh += dxh;
            sum_dxh_xh += dxh * xh;
            dscale.data[j] += dyr[j] * xh;
            dbias.data[j] += dyr[j];
        }
        let inv_c = 1.0 / c as f32;
        let dxr = &mut dx.data[i * c..(i + 1) * c];
        for j in 0..c {
            let xh = (row[j] - mu) * rstd;
            let dxh = dyr[j] * scale.data[j];
            dxr[j] = rstd * (dxh - inv_c * sum_dxh - xh * inv_c * sum_dxh_xh);
        }
    }
    (dx, dscale, dbias)
}

/// Dense causal multi-head attention parameters.
#[derive(Clone, Debug)]
pub struct Attention {
    pub n_heads: usize,
    pub w_qkv: Tensor, // (3d, d)
    pub b_qkv: Tensor, // (3d,)
    pub w_o: Tensor,   // (d, d)
    pub b_o: Tensor,   // (d,)
}

pub struct AttnCache {
    qkv: Tensor,  // (p, 3d)
    /// causal softmax probabilities, row bh = flattened (n, n) score
    /// matrix of (batch, head) pair bh — one tensor so the (batch, head)
    /// work units own disjoint row blocks in the parallel loops
    probs: Tensor, // (batch*heads, n*n)
    ctx: Tensor,  // (p, d) pre-out-proj
}

impl AttnCache {
    /// Probability block of (batch, head) pair `bh` as an (n, n) row-major
    /// slice (tests and diagnostics).
    pub fn probs_of(&self, bh: usize) -> &[f32] {
        let (_, nn) = self.probs.dims2();
        &self.probs.data[bh * nn..(bh + 1) * nn]
    }

    /// Number of (batch, head) probability blocks.
    pub fn n_prob_blocks(&self) -> usize {
        self.probs.dims2().0
    }
}

impl Attention {
    pub fn new(d: usize, n_heads: usize, rng: &mut Rng) -> Self {
        Attention {
            n_heads,
            w_qkv: Tensor::normal(&[3 * d, d], 0.02, rng),
            b_qkv: Tensor::zeros(&[3 * d]),
            w_o: Tensor::normal(&[d, d], 0.02, rng),
            b_o: Tensor::zeros(&[d]),
        }
    }

    /// x: (batch*n, d) with each consecutive n rows one sequence.
    ///
    /// The score/softmax/context loops run on the kernel thread pool, one
    /// (batch, head) pair per work unit: a unit owns probability rows
    /// `bh*n..` and the `head*hd..` column slice of `ctx`, so all writes
    /// are disjoint and per-unit arithmetic is identical whatever the
    /// thread count (same determinism contract as the GEMM kernels).
    pub fn forward(&self, x: &Tensor, batch: usize, n: usize) -> (Tensor, AttnCache) {
        let (p, d) = x.dims2();
        assert_eq!(p, batch * n);
        let h = self.n_heads;
        let hd = d / h;
        let mut qkv = gemm_nt(x, &self.w_qkv);
        add_bias(&mut qkv, &self.b_qkv);
        let mut ctx = Tensor::zeros(&[p, d]);
        let mut probs = Tensor::zeros(&[batch * h, n * n]);
        let scale = 1.0 / (hd as f32).sqrt();
        {
            let ctx_ptr = MutPtr::new(&mut ctx.data);
            let probs_ptr = MutPtr::new(&mut probs.data);
            let qkv_ref = &qkv;
            parallel_rows(batch * h, 1, &|u0, u1| {
                for bh in u0..u1 {
                    let (b, head) = (bh / h, bh % h);
                    let s = unsafe { probs_ptr.range(bh * n * n, (bh + 1) * n * n) };
                    // scores (n, n), causal
                    for i in 0..n {
                        let qi = &qkv_ref.data[(b * n + i) * 3 * d + head * hd
                            ..(b * n + i) * 3 * d + head * hd + hd];
                        for j in 0..=i {
                            let kj = &qkv_ref.data[(b * n + j) * 3 * d + d + head * hd
                                ..(b * n + j) * 3 * d + d + head * hd + hd];
                            s[i * n + j] = super::gemm::dot(qi, kj) * scale;
                        }
                    }
                    // causal softmax row-wise
                    for i in 0..n {
                        let row = &mut s[i * n..i * n + n];
                        let m = row[..=i].iter().cloned().fold(f32::MIN, f32::max);
                        let mut z = 0f32;
                        for j in 0..=i {
                            row[j] = (row[j] - m).exp();
                            z += row[j];
                        }
                        for j in 0..=i {
                            row[j] /= z;
                        }
                        for j in i + 1..n {
                            row[j] = 0.0;
                        }
                    }
                    // ctx = P V (head's column slice of row b*n+i)
                    for i in 0..n {
                        let base = (b * n + i) * d + head * hd;
                        let out = unsafe { ctx_ptr.range(base, base + hd) };
                        for j in 0..=i {
                            let pij = s[i * n + j];
                            if pij == 0.0 {
                                continue;
                            }
                            let vj = &qkv_ref.data[(b * n + j) * 3 * d + 2 * d + head * hd
                                ..(b * n + j) * 3 * d + 2 * d + head * hd + hd];
                            for k in 0..hd {
                                out[k] += pij * vj[k];
                            }
                        }
                    }
                }
            });
        }
        let mut y = gemm_nt(&ctx, &self.w_o);
        add_bias(&mut y, &self.b_o);
        (y, AttnCache { qkv, probs, ctx })
    }

    /// Backward. Returns (dx, dw_qkv, db_qkv, dw_o, db_o).
    pub fn backward(&self, x: &Tensor, cache: &AttnCache, dy: &Tensor,
                    batch: usize, n: usize)
                    -> (Tensor, Tensor, Tensor, Tensor, Tensor) {
        let (p, d) = x.dims2();
        let h = self.n_heads;
        let hd = d / h;
        let scale = 1.0 / (hd as f32).sqrt();
        let dw_o = gemm_tn(dy, &cache.ctx);
        let db_o = col_sum(dy);
        let dctx = gemm_nn(dy, &self.w_o);
        let mut dqkv = Tensor::zeros(&[p, 3 * d]);
        {
            // Same (batch, head) ownership as forward: every dqkv write of
            // unit bh targets rows b*n.. columns head*hd.. of one of the
            // q/k/v thirds — disjoint across units, deterministic across
            // thread counts. dp comes from the worker's thread-local
            // arena, so repeated backwards allocate nothing.
            let dqkv_ptr = MutPtr::new(&mut dqkv.data);
            let (qkv_ref, probs_ref, dctx_ref) = (&cache.qkv, &cache.probs, &dctx);
            parallel_rows(batch * h, 1, &|u0, u1| {
                with_thread_scratch(|ts| {
                    let mut dp = ts.take(&[n, n]);
                    for bh in u0..u1 {
                        let (b, head) = (bh / h, bh % h);
                        let probs = &probs_ref.data[bh * n * n..(bh + 1) * n * n];
                        // dP = dctx V^T ; dV = P^T dctx
                        for i in 0..n {
                            let dci = &dctx_ref.data[(b * n + i) * d + head * hd
                                ..(b * n + i) * d + head * hd + hd];
                            for j in 0..=i {
                                let vj = &qkv_ref.data[(b * n + j) * 3 * d + 2 * d + head * hd
                                    ..(b * n + j) * 3 * d + 2 * d + head * hd + hd];
                                dp.data[i * n + j] = super::gemm::dot(dci, vj);
                                // dV_j += P_ij * dctx_i
                                let pij = probs[i * n + j];
                                if pij != 0.0 {
                                    let vbase = (b * n + j) * 3 * d + 2 * d + head * hd;
                                    let dvj = unsafe { dqkv_ptr.range(vbase, vbase + hd) };
                                    for k in 0..hd {
                                        dvj[k] += pij * dci[k];
                                    }
                                }
                            }
                        }
                        // softmax backward: dS = P ⊙ (dP - rowsum(dP ⊙ P))
                        for i in 0..n {
                            let mut dot = 0f32;
                            for j in 0..=i {
                                dot += dp.data[i * n + j] * probs[i * n + j];
                            }
                            for j in 0..=i {
                                let ds = probs[i * n + j] * (dp.data[i * n + j] - dot) * scale;
                                // dQ_i += dS_ij K_j ; dK_j += dS_ij Q_i
                                if ds == 0.0 {
                                    continue;
                                }
                                let (qi_base, kj_base) = ((b * n + i) * 3 * d + head * hd,
                                                          (b * n + j) * 3 * d + d + head * hd);
                                // q and k thirds never overlap, so the two
                                // ranges are disjoint even when i == j
                                let dqi = unsafe { dqkv_ptr.range(qi_base, qi_base + hd) };
                                let dkj = unsafe { dqkv_ptr.range(kj_base, kj_base + hd) };
                                for k in 0..hd {
                                    let qv = qkv_ref.data[qi_base + k];
                                    let kv = qkv_ref.data[kj_base + k];
                                    dqi[k] += ds * kv;
                                    dkj[k] += ds * qv;
                                }
                            }
                        }
                    }
                    ts.give(dp);
                });
            });
        }
        let dw_qkv = gemm_tn(&dqkv, x);
        let db_qkv = col_sum(&dqkv);
        let dx = gemm_nn(&dqkv, &self.w_qkv);
        (dx, dw_qkv, db_qkv, dw_o, db_o)
    }

    // --- inference-only entry points (serve engine) ----------------------
    //
    // Decode splits the attention forward into three pieces so the engine
    // can batch the GEMMs across sequences while each sequence attends
    // against its own KV cache: qkv_into (batched), attend_cached (per
    // sequence, KV offset), out_proj_into (batched). None of them touch
    // training caches or gradients.

    /// Batched QKV projection: `x` (m, d) -> `qkv` (m, 3d). Row i belongs
    /// to sequence i of the decode batch.
    pub fn qkv_into(&self, x: &Tensor, qkv: &mut Tensor) {
        let (m, _) = x.dims2();
        let (three_d, _) = self.w_qkv.dims2();
        qkv.resize_to(&[m, three_d]);
        gemm_nt_into(x, &self.w_qkv, qkv);
        add_bias(qkv, &self.b_qkv);
    }

    /// One sequence's decode step at KV offset `pos`: append this token's
    /// K/V at row `pos` of the (cap, d) row-major caches and attend
    /// causally over rows `0..=pos`. `qkv_row` is one row of
    /// [`Attention::qkv_into`]'s output; `scores` needs >= pos+1 slots;
    /// `ctx_row` (d) receives the pre-out-projection context. Softmax
    /// arithmetic matches [`Attention::forward`] operation for operation.
    pub fn attend_cached(&self, qkv_row: &[f32], k_cache: &mut [f32],
                         v_cache: &mut [f32], pos: usize,
                         scores: &mut [f32], ctx_row: &mut [f32]) {
        let (d, _) = self.w_o.dims2();
        // Load-bearing release asserts: the body writes through raw
        // MutPtr ranges (debug-only bounds checks), so a too-small
        // cache must be rejected here — in release builds too — where
        // the pre-refactor slice indexing used to panic.
        assert!((pos + 1) * d <= k_cache.len(), "attend_cached: K cache overflow");
        assert!((pos + 1) * d <= v_cache.len(), "attend_cached: V cache overflow");
        let kp = MutPtr::new(k_cache);
        let vp = MutPtr::new(v_cache);
        // SAFETY: kp/vp wrap borrows this call holds exclusively and
        // only this thread touches; every resolved row t*d..t*d+d for
        // t <= pos is in bounds (asserted above).
        unsafe {
            self.attend_token(qkv_row, &kp, &vp, &|t| t * d, pos, scores, ctx_row)
        }
    }

    /// [`Attention::attend_cached`] over page-table-resolved K/V rows:
    /// token row `t` lives at flat offset `row_base(t)` of the pool
    /// storage behind `kp`/`vp` instead of at `t * d` of one flat
    /// slice. Both entry points run the SAME body (`attend_token`)
    /// with different row-base closures, so a paged sequence's logits
    /// match the contiguous pool bitwise by construction — the serve
    /// paged-vs-contiguous differential tests pin it.
    ///
    /// # Safety
    /// Every row `row_base(t)..row_base(t) + d` for `t <= pos` must be
    /// in bounds of both storages and disjoint from every range any
    /// other live thread mutates (the pool guarantees this: distinct
    /// slots own distinct pages).
    pub(crate) unsafe fn attend_cached_paged<F: Fn(usize) -> usize>(
        &self, qkv_row: &[f32], kp: &MutPtr, vp: &MutPtr,
        row_base: &F, pos: usize,
        scores: &mut [f32], ctx_row: &mut [f32],
    ) {
        unsafe { self.attend_token(qkv_row, kp, vp, row_base, pos, scores, ctx_row) }
    }

    /// THE decode body: write one token's K/V row at `row_base(pos)`,
    /// then score/softmax/context per head over rows `0..=pos`. Shared
    /// verbatim by the contiguous (`row_base = t * d`) and paged entry
    /// points; `row_base` is a monomorphized closure, so the contiguous
    /// fast path inlines to the original flat-slice addressing.
    ///
    /// # Safety
    /// Every resolved row range must be in bounds of both storages and
    /// untouched by any other live thread.
    #[inline]
    unsafe fn attend_token<F: Fn(usize) -> usize>(
        &self, qkv_row: &[f32], kp: &MutPtr, vp: &MutPtr,
        row_base: &F, pos: usize,
        scores: &mut [f32], ctx_row: &mut [f32],
    ) {
        let (d, _) = self.w_o.dims2();
        let h = self.n_heads;
        let hd = d / h;
        debug_assert_eq!(qkv_row.len(), 3 * d);
        debug_assert_eq!(ctx_row.len(), d);
        unsafe {
            write_kv_row(qkv_row, d, kp, vp, row_base(pos));
            attend_row(h, hd, scale_of(hd), qkv_row, kp, vp, row_base, pos,
                       scores, ctx_row);
        }
    }

    /// Batched prefill attention for ONE sequence: append a whole chunk
    /// of `c` tokens' K/V at rows `pos0..pos0+c` of the (cap, d)
    /// row-major caches in one contiguous pass, then attend each chunk
    /// row causally over cache rows `0..=pos0+i` — within-chunk and
    /// against already-cached context at once. `qkv` is the chunk's
    /// (c, 3d) projection from [`Attention::qkv_into`]; `scores`
    /// provides `c` rows of `cap` slots; `ctx` (c, d) receives the
    /// pre-out-projection contexts.
    ///
    /// The K/V writes complete before any row attends, so rows run on
    /// the kernel pool in parallel (each owns its scores/ctx row, the
    /// caches are read-only by then). Per-row arithmetic IS
    /// [`Attention::attend_cached`]'s body (both call the shared
    /// `attend_row` core), which is what lets chunked prefill reproduce
    /// the one-token reference path (`InferEngine::prefill_reference`)
    /// to float precision.
    pub fn attend_prefill(&self, qkv: &Tensor, k_cache: &mut [f32],
                          v_cache: &mut [f32], pos0: usize, cap: usize,
                          scores: &mut Tensor, ctx: &mut Tensor) {
        let (c, three_d) = qkv.dims2();
        let d = three_d / 3;
        // Load-bearing release asserts (see attend_cached): the chunk
        // body writes K/V through raw MutPtr ranges.
        assert!(pos0 + c <= cap, "attend_prefill: chunk overflows KV cap");
        assert!(cap * d <= k_cache.len() && cap * d <= v_cache.len(),
                "attend_prefill: KV cache shorter than cap");
        let kp = MutPtr::new(k_cache);
        let vp = MutPtr::new(v_cache);
        // SAFETY: kp/vp wrap borrows this call holds exclusively; rows
        // t*d..t*d+d are in bounds for t < cap (asserted above), and the
        // chunk body only reads them once the parallel region starts.
        unsafe { self.attend_chunk(qkv, &kp, &vp, &|t| t * d, pos0, cap, scores, ctx) }
    }

    /// [`Attention::attend_prefill`] over page-table-resolved K/V rows
    /// (see [`Attention::attend_cached_paged`] for the addressing
    /// contract). `score_stride` is the scores-row width (>= pos0 +
    /// chunk; the engine passes the same stride the contiguous path
    /// uses so the scratch buffers are shared). Same body as the
    /// contiguous entry point (the shared `attend_chunk` driver),
    /// different row-base closure — bitwise parity by construction.
    ///
    /// # Safety
    /// As [`Attention::attend_cached_paged`]: all resolved rows in
    /// bounds, and this sequence's pages touched by no other thread.
    pub(crate) unsafe fn attend_prefill_paged<F: Fn(usize) -> usize + Sync>(
        &self, qkv: &Tensor, kp: &MutPtr, vp: &MutPtr,
        row_base: &F, pos0: usize,
        score_stride: usize, scores: &mut Tensor, ctx: &mut Tensor,
    ) {
        unsafe {
            self.attend_chunk(qkv, kp, vp, row_base, pos0, score_stride, scores, ctx)
        }
    }

    /// THE prefill body: serial chunk K/V writes through `row_base`,
    /// then one [`attend_row`] per chunk row on the kernel pool (each
    /// work unit owns its scores row and ctx row; the caches are
    /// read-only by then).
    ///
    /// # Safety
    /// Every resolved row range must be in bounds of both storages and
    /// untouched by any other live thread for the duration of the call.
    #[inline]
    unsafe fn attend_chunk<F: Fn(usize) -> usize + Sync>(
        &self, qkv: &Tensor, kp: &MutPtr, vp: &MutPtr,
        row_base: &F, pos0: usize,
        score_stride: usize, scores: &mut Tensor, ctx: &mut Tensor,
    ) {
        let (c, three_d) = qkv.dims2();
        let d = three_d / 3;
        let h = self.n_heads;
        let hd = d / h;
        debug_assert!(c >= 1);
        debug_assert!(pos0 + c <= score_stride, "scores row too narrow");
        for i in 0..c {
            let row = &qkv.data[i * 3 * d..(i + 1) * 3 * d];
            unsafe { write_kv_row(row, d, kp, vp, row_base(pos0 + i)) };
        }
        ctx.resize_to(&[c, d]);
        scores.resize_to(&[c, score_stride]);
        let scale = scale_of(hd);
        let ctx_ptr = MutPtr::new(&mut ctx.data);
        let scores_ptr = MutPtr::new(&mut scores.data);
        let qkv_ref = &qkv.data;
        parallel_rows(c, 1, &|u0, u1| {
            for i in u0..u1 {
                let pos = pos0 + i;
                let srow =
                    unsafe { scores_ptr.range(i * score_stride, (i + 1) * score_stride) };
                let crow = unsafe { ctx_ptr.range(i * d, (i + 1) * d) };
                let qrow = &qkv_ref[i * 3 * d..(i + 1) * 3 * d];
                unsafe {
                    attend_row(h, hd, scale, qrow, kp, vp, row_base, pos, srow, crow)
                };
            }
        });
    }

    /// Batched output projection of the decode contexts:
    /// `y = ctx W_o^T + b_o`, shapes (m, d) -> (m, d).
    pub fn out_proj_into(&self, ctx: &Tensor, y: &mut Tensor) {
        let (m, _) = ctx.dims2();
        let (d, _) = self.w_o.dims2();
        y.resize_to(&[m, d]);
        gemm_nt_into(ctx, &self.w_o, y);
        add_bias(y, &self.b_o);
    }
}

#[inline]
fn scale_of(hd: usize) -> f32 {
    1.0 / (hd as f32).sqrt()
}

/// Append one token's K/V row at flat offset `base`: the write half of
/// every cached-attention entry point, contiguous and paged alike.
///
/// # Safety
/// `base..base + d` must be in bounds of both storages and untouched by
/// any other live thread.
#[inline(always)]
unsafe fn write_kv_row(qkv_row: &[f32], d: usize, kp: &MutPtr, vp: &MutPtr,
                       base: usize) {
    let krow = unsafe { kp.range(base, base + d) };
    krow.copy_from_slice(&qkv_row[d..2 * d]);
    let vrow = unsafe { vp.range(base, base + d) };
    vrow.copy_from_slice(&qkv_row[2 * d..3 * d]);
}

/// One query row's cached attention: per head, score against K rows
/// `0..=pos`, softmax, then accumulate the context from the V rows.
/// This is the SINGLE body behind `attend_cached`, `attend_prefill`,
/// and their `_paged` twins — `row_base` (an inlinable monomorphized
/// closure) is the only thing that differs, so the paged-vs-contiguous
/// bitwise guarantee holds by construction instead of by keeping four
/// hand-synchronized loops aligned. Softmax arithmetic matches
/// [`Attention::forward`] operation for operation.
///
/// # Safety
/// Every `row_base(t)..row_base(t) + d` for `t <= pos` must be in
/// bounds of both storages and, for the duration of the call, mutated
/// by no other thread (this call only reads them).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn attend_row<F: Fn(usize) -> usize>(
    h: usize, hd: usize, scale: f32, qkv_row: &[f32],
    kp: &MutPtr, vp: &MutPtr, row_base: &F, pos: usize,
    scores: &mut [f32], ctx_row: &mut [f32],
) {
    for head in 0..h {
        let q = &qkv_row[head * hd..head * hd + hd];
        let s = &mut scores[..pos + 1];
        for (t, st) in s.iter_mut().enumerate() {
            let base = row_base(t) + head * hd;
            let kt: &[f32] = unsafe { kp.range(base, base + hd) };
            *st = super::gemm::dot(q, kt) * scale;
        }
        let m = s.iter().cloned().fold(f32::MIN, f32::max);
        let mut z = 0f32;
        for st in s.iter_mut() {
            *st = (*st - m).exp();
            z += *st;
        }
        for st in s.iter_mut() {
            *st /= z;
        }
        let out = &mut ctx_row[head * hd..head * hd + hd];
        out.fill(0.0);
        for (t, &pt) in s.iter().enumerate() {
            let base = row_base(t) + head * hd;
            let vt: &[f32] = unsafe { vp.range(base, base + hd) };
            for k in 0..hd {
                out[k] += pt * vt[k];
            }
        }
    }
}

/// Which FFN variant a block runs.
#[derive(Clone, Debug)]
pub enum FfnKind {
    Dense(DenseFfn),
    Sparse(SparseFfn),
}

/// Pre-LN transformer block: x + Attn(LN(x)); x + FFN(LN(x)).
#[derive(Clone, Debug)]
pub struct TransformerBlock {
    pub d: usize,
    pub ln1_s: Tensor,
    pub ln1_b: Tensor,
    pub attn: Attention,
    pub ln2_s: Tensor,
    pub ln2_b: Tensor,
    pub ffn: FfnKind,
}

pub struct BlockCache {
    h1: Tensor,
    ln1: (Tensor, Vec<f32>, Vec<f32>),
    attn: AttnCache,
    x_mid: Tensor,
    ln2: (Tensor, Vec<f32>, Vec<f32>),
    ffn: FfnCache,
}

impl TransformerBlock {
    pub fn new(d: usize, r: usize, n_heads: usize, sparse: bool, rng: &mut Rng) -> Self {
        TransformerBlock {
            d,
            ln1_s: Tensor::ones(&[d]),
            ln1_b: Tensor::zeros(&[d]),
            attn: Attention::new(d, n_heads, rng),
            ln2_s: Tensor::ones(&[d]),
            ln2_b: Tensor::zeros(&[d]),
            ffn: if sparse {
                FfnKind::Sparse(SparseFfn::new(d, r, rng))
            } else {
                FfnKind::Dense(DenseFfn::new(d, r, rng))
            },
        }
    }

    pub fn forward(&self, x: &Tensor, batch: usize, n: usize) -> (Tensor, BlockCache) {
        let ln1 = layer_norm(x, &self.ln1_s, &self.ln1_b);
        let (a, attn_cache) = self.attn.forward(&ln1.0, batch, n);
        let mut x_mid = x.clone();
        for (o, v) in x_mid.data.iter_mut().zip(&a.data) {
            *o += v;
        }
        let ln2 = layer_norm(&x_mid, &self.ln2_s, &self.ln2_b);
        let (f, ffn_cache) = match &self.ffn {
            FfnKind::Dense(ffn) => ffn.forward(&ln2.0),
            FfnKind::Sparse(ffn) => ffn.forward(&ln2.0),
        };
        let mut y = x_mid.clone();
        for (o, v) in y.data.iter_mut().zip(&f.data) {
            *o += v;
        }
        (y, BlockCache { h1: x.clone(), ln1, attn: attn_cache, x_mid, ln2, ffn: ffn_cache })
    }

    /// Full backward; returns dx and discards parameter grads not needed by
    /// the speed benches (FFN grads returned for inspection).
    pub fn backward(&self, cache: &BlockCache, dy: &Tensor, batch: usize,
                    n: usize, rng: &mut Rng) -> (Tensor, FfnGrads) {
        // FFN branch
        let ffn_grads = match &self.ffn {
            FfnKind::Dense(ffn) => ffn.backward(&cache.ln2.0, &cache.ffn, dy),
            FfnKind::Sparse(ffn) => ffn.backward(&cache.ln2.0, &cache.ffn, dy, rng),
        };
        let (dln2, _, _) = layer_norm_grad(&cache.x_mid, &self.ln2_s,
                                           &cache.ln2.1, &cache.ln2.2,
                                           &ffn_grads.dx);
        // d x_mid = dy (residual) + dln2
        let mut dxm = dy.clone();
        for (o, v) in dxm.data.iter_mut().zip(&dln2.data) {
            *o += v;
        }
        // attention branch
        let (da, _, _, _, _) = self.attn.backward(&cache.ln1.0, &cache.attn,
                                                  &dxm, batch, n);
        let (dln1, _, _) = layer_norm_grad(&cache.h1, &self.ln1_s,
                                           &cache.ln1.1, &cache.ln1.2, &da);
        let mut dx = dxm;
        for (o, v) in dx.data.iter_mut().zip(&dln1.data) {
            *o += v;
        }
        (dx, ffn_grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        Tensor::normal(shape, 0.5, &mut Rng::new(seed))
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = rand(&[4, 16], 0);
        let (y, _, _) = layer_norm(&x, &Tensor::ones(&[16]), &Tensor::zeros(&[16]));
        for i in 0..4 {
            let row = &y.data[i * 16..(i + 1) * 16];
            let mu: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / 16.0;
            assert!(mu.abs() < 1e-5 && (var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn layer_norm_grad_finite_difference() {
        let x = rand(&[2, 8], 1);
        let s = rand(&[8], 2);
        let b = rand(&[8], 3);
        let (_, means, rstds) = layer_norm(&x, &s, &b);
        let dy = Tensor::ones(&[2, 8]);
        let (dx, _, _) = layer_norm_grad(&x, &s, &means, &rstds, &dy);
        let h = 1e-3f32;
        for k in 0..x.len() {
            let mut xp = x.clone();
            xp.data[k] += h;
            let mut xm = x.clone();
            xm.data[k] -= h;
            let fd = ((layer_norm(&xp, &s, &b).0.sum()
                - layer_norm(&xm, &s, &b).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((dx.data[k] - fd).abs() < 1e-2, "k={k}");
        }
    }

    #[test]
    fn attention_causality() {
        // output at position i must not depend on inputs at positions > i
        let mut rng = Rng::new(4);
        let attn = Attention::new(8, 2, &mut rng);
        let x1 = rand(&[4, 8], 5);
        let mut x2 = x1.clone();
        // perturb the LAST position only
        for j in 0..8 {
            x2.data[3 * 8 + j] += 1.0;
        }
        let (y1, _) = attn.forward(&x1, 1, 4);
        let (y2, _) = attn.forward(&x2, 1, 4);
        for i in 0..3 {
            for j in 0..8 {
                assert!((y1.data[i * 8 + j] - y2.data[i * 8 + j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn attention_probs_rows_sum_to_one() {
        let mut rng = Rng::new(6);
        let attn = Attention::new(8, 2, &mut rng);
        let x = rand(&[6, 8], 7);
        let (_, cache) = attn.forward(&x, 1, 6);
        assert_eq!(cache.n_prob_blocks(), 2);
        for bh in 0..cache.n_prob_blocks() {
            let p = cache.probs_of(bh);
            for i in 0..6 {
                let s: f32 = p[i * 6..(i + 1) * 6].iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn forward_bitwise_invariant_in_thread_count() {
        use crate::sparse::kernels::set_num_threads;
        let mut rng = Rng::new(20);
        let attn = Attention::new(32, 4, &mut rng);
        let x = rand(&[2 * 16, 32], 21);
        let prev = crate::sparse::kernels::num_threads();
        set_num_threads(1);
        let (y1, _) = attn.forward(&x, 2, 16);
        set_num_threads(4);
        let (y4, _) = attn.forward(&x, 2, 16);
        set_num_threads(prev);
        assert_eq!(y1, y4, "attention must be bitwise thread-count invariant");
    }

    #[test]
    fn attend_cached_matches_full_forward() {
        // incremental decode through the KV cache reproduces the full
        // causal forward's last-row output
        let (d, h, n) = (16, 2, 5);
        let mut rng = Rng::new(30);
        let attn = Attention::new(d, h, &mut rng);
        let x = rand(&[n, d], 31);
        let (y_full, _) = attn.forward(&x, 1, n);
        let mut k_cache = vec![0f32; n * d];
        let mut v_cache = vec![0f32; n * d];
        let mut scores = vec![0f32; n];
        let mut ctx = Tensor::zeros(&[1, d]);
        let mut qkv = Tensor::zeros(&[0]);
        let mut y = Tensor::zeros(&[0]);
        for t in 0..n {
            let xt = Tensor::from_vec(&[1, d], x.data[t * d..(t + 1) * d].to_vec());
            attn.qkv_into(&xt, &mut qkv);
            attn.attend_cached(&qkv.data, &mut k_cache, &mut v_cache, t,
                               &mut scores, &mut ctx.data);
            attn.out_proj_into(&ctx, &mut y);
            for j in 0..d {
                assert!((y.data[j] - y_full.data[t * d + j]).abs() < 1e-5,
                        "t={t} j={j}: {} vs {}", y.data[j], y_full.data[t * d + j]);
            }
        }
    }

    #[test]
    fn attend_prefill_matches_attend_cached_and_full_forward() {
        // a chunked prefill over [cached prefix | chunk] reproduces both
        // the token-at-a-time attend_cached path and the full forward
        let (d, h, n, cap) = (16, 2, 6, 8);
        let mut rng = Rng::new(40);
        let attn = Attention::new(d, h, &mut rng);
        let x = rand(&[n, d], 41);
        let (y_full, _) = attn.forward(&x, 1, n);

        for prefix in [0usize, 2] {
            // reference caches via attend_cached, one token at a time
            let mut k_ref = vec![0f32; cap * d];
            let mut v_ref = vec![0f32; cap * d];
            let mut srow = vec![0f32; cap];
            let mut ctx1 = Tensor::zeros(&[1, d]);
            let mut qkv = Tensor::zeros(&[0]);
            let mut ref_ctx = Tensor::zeros(&[n, d]);
            for t in 0..n {
                let xt = Tensor::from_vec(&[1, d], x.data[t * d..(t + 1) * d].to_vec());
                attn.qkv_into(&xt, &mut qkv);
                attn.attend_cached(&qkv.data, &mut k_ref, &mut v_ref, t,
                                   &mut srow, &mut ctx1.data);
                ref_ctx.data[t * d..(t + 1) * d].copy_from_slice(&ctx1.data);
            }
            // chunked: prefix tokens one at a time, the rest in one chunk
            let mut k = vec![0f32; cap * d];
            let mut v = vec![0f32; cap * d];
            for t in 0..prefix {
                let xt = Tensor::from_vec(&[1, d], x.data[t * d..(t + 1) * d].to_vec());
                attn.qkv_into(&xt, &mut qkv);
                attn.attend_cached(&qkv.data, &mut k, &mut v, t,
                                   &mut srow, &mut ctx1.data);
            }
            let c = n - prefix;
            let xc = Tensor::from_vec(&[c, d], x.data[prefix * d..n * d].to_vec());
            attn.qkv_into(&xc, &mut qkv);
            let mut scores = Tensor::zeros(&[0]);
            let mut ctx = Tensor::zeros(&[0]);
            attn.attend_prefill(&qkv, &mut k, &mut v, prefix, cap,
                                &mut scores, &mut ctx);
            // cache rows identical; contexts match the reference path
            assert_eq!(&k[..n * d], &k_ref[..n * d], "prefix {prefix}: K rows");
            assert_eq!(&v[..n * d], &v_ref[..n * d], "prefix {prefix}: V rows");
            for i in 0..c {
                for j in 0..d {
                    let (a, b) = (ctx.data[i * d + j], ref_ctx.data[(prefix + i) * d + j]);
                    assert!((a - b).abs() < 1e-6,
                            "prefix {prefix} row {i} col {j}: {a} vs {b}");
                }
            }
            // and the projected outputs match the full causal forward
            let mut y = Tensor::zeros(&[0]);
            attn.out_proj_into(&ctx, &mut y);
            for i in 0..c {
                for j in 0..d {
                    let (a, b) = (y.data[i * d + j], y_full.data[(prefix + i) * d + j]);
                    assert!((a - b).abs() < 1e-5,
                            "prefix {prefix} out row {i} col {j}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn attention_backward_finite_difference() {
        let mut rng = Rng::new(8);
        let attn = Attention::new(4, 1, &mut rng);
        let x = rand(&[3, 4], 9);
        let (_, cache) = attn.forward(&x, 1, 3);
        let dy = Tensor::ones(&[3, 4]);
        let (dx, dwqkv, _, _, _) = attn.backward(&x, &cache, &dy, 1, 3);
        let h = 1e-3f32;
        for k in 0..x.len() {
            let mut xp = x.clone();
            xp.data[k] += h;
            let mut xm = x.clone();
            xm.data[k] -= h;
            let fd = ((attn.forward(&xp, 1, 3).0.sum()
                - attn.forward(&xm, 1, 3).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((dx.data[k] - fd).abs() < 2e-2, "dx k={k}: {} vs {fd}", dx.data[k]);
        }
        for &k in &[0usize, 7, 20] {
            let mut ap = attn.clone();
            ap.w_qkv.data[k] += h;
            let mut am = attn.clone();
            am.w_qkv.data[k] -= h;
            let fd = ((ap.forward(&x, 1, 3).0.sum()
                - am.forward(&x, 1, 3).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((dwqkv.data[k] - fd).abs() < 2e-2, "dwqkv k={k}");
        }
    }

    #[test]
    fn block_forward_backward_shapes() {
        let mut rng = Rng::new(10);
        for sparse in [false, true] {
            let blk = TransformerBlock::new(16, 8, 2, sparse, &mut rng);
            let x = rand(&[8, 16], 11);
            let (y, cache) = blk.forward(&x, 2, 4);
            assert_eq!(y.shape, vec![8, 16]);
            let dy = Tensor::ones(&[8, 16]);
            let (dx, g) = blk.backward(&cache, &dy, 2, 4, &mut rng);
            assert_eq!(dx.shape, vec![8, 16]);
            assert_eq!(g.dw1.shape, vec![16, 16]);
        }
    }

    #[test]
    fn block_backward_finite_difference_dense() {
        let mut rng = Rng::new(12);
        let blk = TransformerBlock::new(8, 4, 2, false, &mut rng);
        let x = rand(&[4, 8], 13);
        let (_, cache) = blk.forward(&x, 1, 4);
        let dy = Tensor::ones(&[4, 8]);
        let (dx, _) = blk.backward(&cache, &dy, 1, 4, &mut rng);
        let h = 1e-3f32;
        for &k in &[0usize, 5, 17, 31] {
            let mut xp = x.clone();
            xp.data[k] += h;
            let mut xm = x.clone();
            xm.data[k] -= h;
            let fd = ((blk.forward(&xp, 1, 4).0.sum()
                - blk.forward(&xm, 1, 4).0.sum()) / (2.0 * h as f64)) as f32;
            assert!((dx.data[k] - fd).abs() < 3e-2, "k={k}: {} vs {fd}", dx.data[k]);
        }
    }
}
