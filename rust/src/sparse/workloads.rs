//! Timed workloads for the paper's speed experiments (Fig. 7, Tables 11/13).
//!
//! Shared by the bench binaries and the `sparse24 speedup` CLI so every
//! figure/table is regenerable from either entry point. All timings are
//! fwd+bwd (matching the paper's measurements) on the CPU substrate:
//! dense GEMMs vs compressed 2:4 spMMs with the full FST overhead model —
//! per-step weight recompression, per-step MVUE, and the transposable-mask
//! search amortized over the refresh interval l (§5.3; paper uses 40).

use std::time::{Duration, Instant};

use crate::sparse::block::TransformerBlock;
use crate::sparse::ffn::{DenseFfn, FfnCache, FfnGrads, SparseFfn};
use crate::sparse::flip::ActFlipMonitor;
use crate::sparse::kernels::Scratch;
use crate::sparse::SparseMode;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Timing for one FFN-layer training iteration (fwd+bwd+overheads).
#[derive(Clone, Debug)]
pub struct FfnTiming {
    pub fwd_s: f64,
    pub bwd_s: f64,
    /// per-iteration overhead: recompress + amortized mask search
    pub overhead_s: f64,
}

impl FfnTiming {
    pub fn total(&self) -> f64 {
        self.fwd_s + self.bwd_s + self.overhead_s
    }
}

fn time_reps(mut f: impl FnMut(), reps: usize) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Pick a repetition count so one measurement takes roughly `budget`.
fn calibrate(mut f: impl FnMut(), budget: Duration) -> usize {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_micros(10));
    ((budget.as_secs_f64() / once.as_secs_f64()) as usize).clamp(2, 200)
}

/// Dense FFN iteration time: p tokens, width d, inner r. Timed through
/// the `_scratch` paths: all buffers are preallocated/recycled, so the
/// measurement is kernel arithmetic, not allocator traffic.
pub fn time_dense_ffn(p: usize, d: usize, r: usize, budget: Duration) -> FfnTiming {
    let mut rng = Rng::new(0xD15E);
    let ffn = DenseFfn::new(d, r, &mut rng);
    let x = Tensor::normal(&[p, d], 0.5, &mut rng);
    let dy = Tensor::normal(&[p, d], 0.5, &mut rng);
    let mut cache = FfnCache::empty();
    let mut y = Tensor::zeros(&[0]);
    let mut grads = FfnGrads::empty();
    let mut scratch = Scratch::new();
    let reps = calibrate(
        || {
            ffn.forward_scratch(&x, &mut cache, &mut y);
            ffn.backward_scratch(&x, &cache, &dy, &mut grads, &mut scratch);
            std::hint::black_box(grads.dw1.data[0]);
        },
        budget,
    );
    let fwd_s = time_reps(
        || {
            ffn.forward_scratch(&x, &mut cache, &mut y);
            std::hint::black_box(y.data[0]);
        },
        reps,
    );
    ffn.forward_scratch(&x, &mut cache, &mut y);
    let bwd_s = time_reps(
        || {
            ffn.backward_scratch(&x, &cache, &dy, &mut grads, &mut scratch);
            std::hint::black_box(grads.dw1.data[0]);
        },
        reps,
    );
    FfnTiming { fwd_s, bwd_s, overhead_s: 0.0 }
}

/// FST 2:4 FFN iteration time with the full overhead model.
/// `mask_interval` = l (mask search cost amortized by 1/l). `mode`
/// selects the sparse operand family: in `Activation` (and `Both`) the
/// forward includes the per-batch activation prune, and the
/// activation-mask churn feeds an [`ActFlipMonitor`] (so the
/// `sparse.flip.activation` gauge is live whenever metrics are on).
/// Weight-side overheads (recompress + amortized mask search) only
/// apply when the mode keeps the weights 2:4 — pure activation mode has
/// no weight masks to maintain, so its `overhead_s` is zero.
pub fn time_sparse_ffn(p: usize, d: usize, r: usize, mask_interval: usize,
                       mode: SparseMode, budget: Duration) -> FfnTiming {
    let mut rng = Rng::new(0x5EED);
    let mut ffn = SparseFfn::new_with_mode(d, r, mode, &mut rng);
    let x = Tensor::normal(&[p, d], 0.5, &mut rng);
    let dy = Tensor::normal(&[p, d], 0.5, &mut rng);
    let mut cache = FfnCache::empty();
    let mut y = Tensor::zeros(&[0]);
    let mut grads = FfnGrads::empty();
    let mut scratch = Scratch::new();
    let mut flips = ActFlipMonitor::new();
    let mut crng = Rng::new(1);
    let reps = calibrate(
        || {
            ffn.forward_scratch(&x, &mut cache, &mut y);
            ffn.backward_scratch(&x, &cache, &dy, &mut crng, &mut grads, &mut scratch);
            std::hint::black_box(grads.dw1.data[0]);
        },
        budget,
    );
    let fwd_s = time_reps(
        || {
            ffn.forward_scratch(&x, &mut cache, &mut y);
            if mode.sparse_activations() {
                flips.observe(&cache.act_mask);
            }
            std::hint::black_box(y.data[0]);
        },
        reps,
    );
    ffn.forward_scratch(&x, &mut cache, &mut y);
    let mut brng = Rng::new(2);
    let bwd_s = time_reps(
        || {
            ffn.backward_scratch(&x, &cache, &dy, &mut brng, &mut grads, &mut scratch);
            std::hint::black_box(grads.dw1.data[0]);
        },
        reps,
    );
    // per-step prune (recompress) + amortized transposable search
    let overhead_s = if mode.sparse_weights() {
        let recompress_s = time_reps(|| ffn.recompress(), reps.max(5));
        let search_s = time_reps(|| ffn.refresh_masks(), (reps / 4).max(3));
        recompress_s + search_s / mask_interval as f64
    } else {
        0.0
    };
    FfnTiming { fwd_s, bwd_s, overhead_s }
}

/// Fig. 7a row: FFN speedup S = dense/sparse at (n tokens, d, r=4d),
/// with the sparse side running under `mode`.
pub fn ffn_speedup(p: usize, d: usize, mode: SparseMode, budget: Duration)
                   -> (f64, f64, f64) {
    let r = 4 * d;
    let dense = time_dense_ffn(p, d, r, budget);
    let sparse = time_sparse_ffn(p, d, r, 40, mode, budget);
    (dense.total(), sparse.total(), dense.total() / sparse.total())
}

/// Timing for one transformer-block training iteration.
pub fn time_block(batch: usize, n: usize, d: usize, heads: usize, sparse: bool,
                  budget: Duration) -> f64 {
    let mut rng = Rng::new(0xB10C);
    let blk = TransformerBlock::new(d, 4 * d, heads, sparse, &mut rng);
    let p = batch * n;
    let x = Tensor::normal(&[p, d], 0.5, &mut rng);
    let dy = Tensor::normal(&[p, d], 0.5, &mut rng);
    let mut brng = Rng::new(3);
    let reps = calibrate(
        || {
            let (_, c) = blk.forward(&x, batch, n);
            std::hint::black_box(blk.backward(&c, &dy, batch, n, &mut brng).0.data[0]);
        },
        budget,
    );
    time_reps(
        || {
            let (_, c) = blk.forward(&x, batch, n);
            std::hint::black_box(blk.backward(&c, &dy, batch, n, &mut brng).0.data[0]);
        },
        reps,
    )
}

/// Fig. 7b-d row: block speedup at (batch, n, d).
pub fn block_speedup(batch: usize, n: usize, d: usize, heads: usize,
                     budget: Duration) -> (f64, f64, f64) {
    let dense = time_block(batch, n, d, heads, false, budget);
    let sparse = time_block(batch, n, d, heads, true, budget);
    (dense, sparse, dense / sparse)
}

/// Table 11: end-to-end model iteration (L blocks) speedup.
pub fn e2e_speedup(layers: usize, batch: usize, n: usize, d: usize, heads: usize,
                   budget: Duration) -> (f64, f64, f64) {
    let per_block_budget =
        Duration::from_secs_f64(budget.as_secs_f64() / layers as f64);
    // blocks are independent in cost; time one of each kind and scale,
    // plus the (dense) embedding/head cost approximated by one extra
    // dense-attention-free share — matches the paper's "Others" rows.
    let dense = time_block(batch, n, d, heads, false, per_block_budget) * layers as f64;
    let sparse = time_block(batch, n, d, heads, true, per_block_budget) * layers as f64;
    // LM head / embeddings: identical in both (dense GEMMs), measured as
    // ~15% of dense block stack cost on GPT-2-like shapes (Table 13's
    // "Others" outside blocks). Add symmetrically.
    let others = 0.15 * dense;
    let (dt, st) = (dense + others, sparse + others);
    (dt, st, dt / st)
}

/// Table 13 reproduction: component time breakdown of one sparse block
/// iteration vs its dense twin. Returns (name, dense_ms, sparse_ms) rows.
pub fn profile_breakdown(batch: usize, n: usize, d: usize,
                         budget: Duration) -> Vec<(String, f64, f64)> {
    let p = batch * n;
    let r = 4 * d;
    let mut rng = Rng::new(0x60D);
    let dense = time_dense_ffn(p, d, r, budget);
    let sparse = time_sparse_ffn(p, d, r, 40, SparseMode::Weight, budget);
    let mut sf = SparseFfn::new(d, r, &mut rng);
    let recompress_s = time_reps(|| sf.recompress(), 10);
    let search_s = time_reps(|| sf.refresh_masks(), 5);
    let dense_blk = time_block(batch, n, d, (d / 64).max(1), false, budget);
    let sparse_blk = time_block(batch, n, d, (d / 64).max(1), true, budget);
    vec![
        ("ffn_fwd".into(), dense.fwd_s * 1e3, sparse.fwd_s * 1e3),
        ("ffn_bwd".into(), dense.bwd_s * 1e3, sparse.bwd_s * 1e3),
        ("prune_weights(recompress)".into(), 0.0, recompress_s * 1e3),
        ("transposable_mask_search".into(), 0.0, search_s * 1e3),
        ("mask_search_amortized(l=40)".into(), 0.0, search_s * 1e3 / 40.0),
        ("block_total".into(), dense_blk * 1e3, sparse_blk * 1e3),
        (
            "others(block - ffn)".into(),
            (dense_blk - dense.fwd_s - dense.bwd_s) * 1e3,
            (sparse_blk - sparse.fwd_s - sparse.bwd_s) * 1e3,
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    const FAST: Duration = Duration::from_millis(30);

    #[test]
    fn ffn_timings_positive() {
        let t = time_dense_ffn(64, 16, 64, FAST);
        assert!(t.fwd_s > 0.0 && t.bwd_s > 0.0);
        let s = time_sparse_ffn(64, 16, 64, 40, SparseMode::Weight, FAST);
        assert!(s.fwd_s > 0.0 && s.overhead_s > 0.0);
    }

    #[test]
    fn speedup_is_finite_and_positive() {
        let (d, s, ratio) = ffn_speedup(64, 16, SparseMode::Weight, FAST);
        assert!(d > 0.0 && s > 0.0 && ratio > 0.1 && ratio < 10.0);
    }

    /// Activation mode: no weight masks to maintain (zero overhead) and
    /// the activation-churn monitor sees the per-iteration masks.
    #[test]
    fn activation_mode_timing_has_no_weight_overhead() {
        let s = time_sparse_ffn(64, 16, 64, 40, SparseMode::Activation, FAST);
        assert!(s.fwd_s > 0.0 && s.bwd_s > 0.0);
        assert_eq!(s.overhead_s, 0.0);
        let b = time_sparse_ffn(64, 16, 64, 40, SparseMode::Both, FAST);
        assert!(b.overhead_s > 0.0);
    }

    #[test]
    fn block_speedup_runs() {
        let (d, s, ratio) = block_speedup(1, 16, 16, 2, FAST);
        assert!(d > 0.0 && s > 0.0 && ratio > 0.0);
    }

    #[test]
    fn profile_rows_cover_components() {
        let rows = profile_breakdown(1, 16, 16, FAST);
        let names: Vec<&str> = rows.iter().map(|r| r.0.as_str()).collect();
        assert!(names.contains(&"ffn_fwd"));
        assert!(names.contains(&"transposable_mask_search"));
    }
}
