//! Transposable 2:4 mask search (paper §5.1, Algorithm 1).
//!
//! The paper's key implementation insight: instead of Hubara et al.'s
//! branchy sort-and-pick per 4x4 block, enumerate the full bank of 90
//! valid patterns OFFLINE (a 4x4 binary matrix with exactly two 1s per row
//! and per column) and pick, per block, the pattern maximizing the retained
//! L1 norm — expressed on GPU as conv2d(|W|, bank, stride=4) + argmax.
//!
//! On CPU the same search is a dense dot of each block's 16 |w| values
//! against the 90x16 bank. We precompute the bank once (`once_cell`) and
//! keep the inner loop branch-free; see `two_approx.rs` for the baseline
//! this beats (Table 3) and `rust/benches/table3_mask_search.rs` for the
//! reproduction bench.

use once_cell::sync::Lazy;

use super::mask::Mask;
use crate::tensor::Tensor;

/// The 90 valid patterns, each as 16 f32s in row-major 4x4 order.
pub static PATTERNS: Lazy<Vec<[f32; 16]>> = Lazy::new(generate_patterns);

/// Same bank with each pattern as a u16 bitmask (bit k = entry k).
pub static PATTERN_BITS: Lazy<Vec<u16>> = Lazy::new(|| {
    PATTERNS
        .iter()
        .map(|p| {
            p.iter()
                .enumerate()
                .fold(0u16, |acc, (k, &v)| if v != 0.0 { acc | (1 << k) } else { acc })
        })
        .collect()
});

fn generate_patterns() -> Vec<[f32; 16]> {
    // all 4-bit values with exactly two bits set — the 6 valid row patterns
    let rows: Vec<u8> = (0u8..16).filter(|r| r.count_ones() == 2).collect();
    let mut out = Vec::new();
    for &a in &rows {
        for &b in &rows {
            for &c in &rows {
                // column sums so far must not exceed 2; the last row is
                // uniquely determined by the deficit
                let mut d: u8 = 0;
                let mut ok = true;
                for bit in 0..4 {
                    let col = ((a >> bit) & 1) + ((b >> bit) & 1) + ((c >> bit) & 1);
                    if col > 2 {
                        ok = false;
                        break;
                    }
                    if col == 1 {
                        d |= 1 << bit;
                    }
                }
                if !ok || d.count_ones() != 2 {
                    continue;
                }
                let mut pat = [0f32; 16];
                for (i, r) in [a, b, c, d].into_iter().enumerate() {
                    for bit in 0..4 {
                        pat[i * 4 + bit] = ((r >> bit) & 1) as f32;
                    }
                }
                out.push(pat);
            }
        }
    }
    assert_eq!(out.len(), 90, "mask diversity must be 90");
    out
}

/// Optimal transposable mask of a 2-D tensor (dims multiples of 4).
///
/// Exhaustive over the bank => exactly maximizes ||M ⊙ W||_1 per block
/// (the conv-search of Algorithm 1). O(90·16) MACs per 4x4 block.
pub fn transposable_mask(w: &Tensor) -> Mask {
    let (r, c) = w.dims2();
    assert!(r % 4 == 0 && c % 4 == 0, "shape ({r},{c}) not 4x4-aligned");
    let mut mask = Mask::zeros(r, c);
    let mut block = [0f32; 16];
    for bi in (0..r).step_by(4) {
        for bj in (0..c).step_by(4) {
            load_abs_block(w, bi, bj, &mut block);
            let best = best_pattern(&block);
            let pat = &PATTERNS[best];
            for k in 0..4 {
                for l in 0..4 {
                    mask.data[(bi + k) * c + (bj + l)] = pat[k * 4 + l] as u8;
                }
            }
        }
    }
    mask
}

#[inline]
fn load_abs_block(w: &Tensor, bi: usize, bj: usize, out: &mut [f32; 16]) {
    let c = w.shape[1];
    for k in 0..4 {
        let row = &w.data[(bi + k) * c + bj..(bi + k) * c + bj + 4];
        out[k * 4] = row[0].abs();
        out[k * 4 + 1] = row[1].abs();
        out[k * 4 + 2] = row[2].abs();
        out[k * 4 + 3] = row[3].abs();
    }
}

/// argmax over the 90 patterns of dot(pattern, |block|); ties -> lower idx.
#[inline]
pub fn best_pattern(abs_block: &[f32; 16]) -> usize {
    let mut best = 0usize;
    let mut best_score = f32::MIN;
    for (p, pat) in PATTERNS.iter().enumerate() {
        let mut s = 0f32;
        for k in 0..16 {
            s += pat[k] * abs_block[k];
        }
        if s > best_score {
            best_score = s;
            best = p;
        }
    }
    best
}

/// Retained L1 norm of a mask applied to |w| — the search objective.
pub fn retained_l1(w: &Tensor, m: &Mask) -> f64 {
    w.data
        .iter()
        .zip(&m.data)
        .map(|(&x, &b)| if b != 0 { x.abs() as f64 } else { 0.0 })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bank_has_90_unique_valid_patterns() {
        assert_eq!(PATTERNS.len(), 90);
        let mut seen = std::collections::HashSet::new();
        for pat in PATTERNS.iter() {
            assert!(seen.insert(pat.iter().map(|&v| v as u8).collect::<Vec<_>>()));
            for i in 0..4 {
                let row: f32 = (0..4).map(|j| pat[i * 4 + j]).sum();
                let col: f32 = (0..4).map(|j| pat[j * 4 + i]).sum();
                assert_eq!(row, 2.0);
                assert_eq!(col, 2.0);
            }
        }
    }

    #[test]
    fn mask_is_transposable_and_24_both_ways() {
        let mut rng = Rng::new(0);
        let w = Tensor::normal(&[16, 32], 1.0, &mut rng);
        let m = transposable_mask(&w);
        assert!(m.is_transposable());
        assert!(m.is_24_row_wise());
        assert!(m.transpose().is_24_row_wise()); // Eq. 5
    }

    #[test]
    fn beats_or_ties_every_single_pattern() {
        let mut rng = Rng::new(1);
        let w = Tensor::normal(&[4, 4], 1.0, &mut rng);
        let m = transposable_mask(&w);
        let ours = retained_l1(&w, &m);
        for pat in PATTERNS.iter() {
            let score: f64 = (0..16)
                .map(|k| pat[k] as f64 * w.data[k].abs() as f64)
                .sum();
            assert!(ours >= score - 1e-9);
        }
    }

    #[test]
    fn identity_structure_recovered() {
        // weight with an obviously optimal transposable support
        let mut w = Tensor::zeros(&[4, 4]);
        for (i, j) in [(0, 0), (0, 1), (1, 0), (1, 1), (2, 2), (2, 3), (3, 2), (3, 3)] {
            *w.at_mut(i, j) = 10.0;
        }
        let m = transposable_mask(&w);
        assert_eq!(retained_l1(&w, &m), 80.0);
    }

    #[test]
    fn pattern_bits_agree_with_patterns() {
        for (pat, &bits) in PATTERNS.iter().zip(PATTERN_BITS.iter()) {
            for k in 0..16 {
                assert_eq!(pat[k] != 0.0, bits & (1 << k) != 0);
            }
        }
    }
}
