//! 2:4 sparsity masks and magnitude pruning (paper Eq. 2-3, Appendix A.1).
//!
//! A [`Mask`] is a {0,1} byte matrix aligned with a weight tensor. The
//! magnitude pruners match the python oracle (`kernels/ref.py`) exactly:
//! keep the two largest |w| of each consecutive group of four, ties broken
//! toward the LOWER index.

use crate::tensor::Tensor;

/// {0,1} mask with the same (row-major) layout as its weight tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

impl Mask {
    pub fn ones(rows: usize, cols: usize) -> Self {
        Mask { rows, cols, data: vec![1; rows * cols] }
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mask { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> u8 {
        self.data[i * self.cols + j]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn count_ones(&self) -> usize {
        self.data.iter().map(|&b| b as usize).sum()
    }

    /// Number of positions where the two masks differ (Definition 4.1's
    /// numerator ||m_t - m_{t-1}||_1).
    pub fn hamming(&self, other: &Mask) -> usize {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// Apply to a weight tensor: W ⊙ M.
    pub fn apply(&self, w: &Tensor) -> Tensor {
        let (r, c) = w.dims2();
        assert_eq!((r, c), (self.rows, self.cols));
        let data = w
            .data
            .iter()
            .zip(&self.data)
            .map(|(&x, &m)| if m != 0 { x } else { 0.0 })
            .collect();
        Tensor { shape: w.shape.clone(), data }
    }

    /// Apply in place (hot path in the trainer: no allocation).
    pub fn apply_into(&self, w: &Tensor, out: &mut Tensor) {
        assert_eq!(w.shape, out.shape);
        for ((o, &x), &m) in out.data.iter_mut().zip(&w.data).zip(&self.data) {
            *o = if m != 0 { x } else { 0.0 };
        }
    }

    pub fn transpose(&self) -> Mask {
        let mut out = Mask::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Is every consecutive group of 4 along rows exactly 2-sparse?
    pub fn is_24_row_wise(&self) -> bool {
        if self.cols % 4 != 0 {
            return false;
        }
        self.data
            .chunks_exact(4)
            .all(|g| g.iter().map(|&b| b as usize).sum::<usize>() == 2)
    }

    /// Transposable validity: every 4x4 block has 2 ones per row AND column.
    pub fn is_transposable(&self) -> bool {
        if self.rows % 4 != 0 || self.cols % 4 != 0 {
            return false;
        }
        for bi in (0..self.rows).step_by(4) {
            for bj in (0..self.cols).step_by(4) {
                for k in 0..4 {
                    let row_sum: u8 = (0..4).map(|l| self.at(bi + k, bj + l)).sum();
                    let col_sum: u8 = (0..4).map(|l| self.at(bi + l, bj + k)).sum();
                    if row_sum != 2 || col_sum != 2 {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// As f32 tensor (for feeding the XLA executables).
    pub fn to_tensor(&self) -> Tensor {
        Tensor {
            shape: vec![self.rows, self.cols],
            data: self.data.iter().map(|&b| b as f32).collect(),
        }
    }
}

/// Index pair of the two kept elements of a group of four: the two largest
/// |w|, ties toward the lower index. Branch-light and allocation-free.
#[inline]
pub fn top2_of4(g: &[f32]) -> (usize, usize) {
    debug_assert_eq!(g.len(), 4);
    let mut best = 0usize;
    for k in 1..4 {
        if g[k].abs() > g[best].abs() {
            best = k;
        }
    }
    let mut second = usize::MAX;
    for k in 0..4 {
        if k == best {
            continue;
        }
        if second == usize::MAX || g[k].abs() > g[second].abs() {
            second = k;
        }
    }
    if best < second {
        (best, second)
    } else {
        (second, best)
    }
}

/// Row-wise magnitude 2:4 mask of a 2-D tensor (cols % 4 == 0).
pub fn prune24_mask(w: &Tensor) -> Mask {
    let (r, c) = w.dims2();
    assert_eq!(c % 4, 0, "cols {c} not a multiple of 4");
    let mut mask = Mask::zeros(r, c);
    for (g, m) in w.data.chunks_exact(4).zip(mask.data.chunks_exact_mut(4)) {
        let (a, b) = top2_of4(g);
        m[a] = 1;
        m[b] = 1;
    }
    mask
}

/// Row-wise magnitude 2:4 pruning: W ⊙ prune24_mask(W).
pub fn prune24(w: &Tensor) -> Tensor {
    prune24_mask(w).apply(w)
}

/// Column-wise 2:4 mask: groups of four run down each column
/// (equals prune24 of the transpose, transposed back).
pub fn prune24_mask_colwise(w: &Tensor) -> Mask {
    let (r, c) = w.dims2();
    assert_eq!(r % 4, 0, "rows {r} not a multiple of 4");
    let mut mask = Mask::zeros(r, c);
    let mut g = [0f32; 4];
    for j in 0..c {
        for bi in (0..r).step_by(4) {
            for k in 0..4 {
                g[k] = w.data[(bi + k) * c + j];
            }
            let (a, b) = top2_of4(&g);
            mask.data[(bi + a) * c + j] = 1;
            mask.data[(bi + b) * c + j] = 1;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top2_basics() {
        assert_eq!(top2_of4(&[1.0, -3.0, 2.0, -0.5]), (1, 2));
        assert_eq!(top2_of4(&[0.0, 0.0, 5.0, 1.0]), (2, 3));
        // ties -> lower indices
        assert_eq!(top2_of4(&[2.0, 2.0, 2.0, 2.0]), (0, 1));
        assert_eq!(top2_of4(&[0.0, 0.0, 0.0, 0.0]), (0, 1));
    }

    #[test]
    fn prune_keeps_top2() {
        let w = Tensor::from_vec(&[2, 4], vec![1., -3., 2., -0.5, 0., 0., 5., 1.]);
        let p = prune24(&w);
        assert_eq!(p.data, vec![0., -3., 2., 0., 0., 0., 5., 1.]);
    }

    #[test]
    fn mask_is_24_valid() {
        let mut rng = crate::util::rng::Rng::new(1);
        let w = Tensor::normal(&[16, 32], 1.0, &mut rng);
        let m = prune24_mask(&w);
        assert!(m.is_24_row_wise());
        assert_eq!(m.count_ones(), 16 * 32 / 2);
    }

    #[test]
    fn colwise_equals_transposed_rowwise() {
        let mut rng = crate::util::rng::Rng::new(2);
        let w = Tensor::normal(&[8, 12], 1.0, &mut rng);
        let a = prune24_mask_colwise(&w);
        let b = prune24_mask(&w.t()).transpose();
        assert_eq!(a, b);
    }

    #[test]
    fn hamming_and_apply() {
        let a = Mask { rows: 1, cols: 4, data: vec![1, 1, 0, 0] };
        let b = Mask { rows: 1, cols: 4, data: vec![1, 0, 1, 0] };
        assert_eq!(a.hamming(&b), 2);
        let w = Tensor::from_vec(&[1, 4], vec![5., 6., 7., 8.]);
        assert_eq!(a.apply(&w).data, vec![5., 6., 0., 0.]);
    }

    #[test]
    fn apply_into_matches_apply() {
        let mut rng = crate::util::rng::Rng::new(3);
        let w = Tensor::normal(&[4, 8], 1.0, &mut rng);
        let m = prune24_mask(&w);
        let mut out = Tensor::zeros(&[4, 8]);
        m.apply_into(&w, &mut out);
        assert_eq!(out, m.apply(&w));
    }

    #[test]
    fn transposable_check() {
        // the identity-pair pattern: rows 1100/1100/0011/0011 is transposable
        let m = Mask {
            rows: 4,
            cols: 4,
            data: vec![1, 1, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 1, 1],
        };
        assert!(m.is_transposable());
        let bad = Mask { rows: 4, cols: 4, data: vec![1; 16] };
        assert!(!bad.is_transposable());
    }

    #[test]
    fn prune_idempotent() {
        let mut rng = crate::util::rng::Rng::new(4);
        let w = Tensor::normal(&[8, 16], 1.0, &mut rng);
        let once = prune24(&w);
        let twice = prune24(&once);
        assert_eq!(once, twice);
    }
}
