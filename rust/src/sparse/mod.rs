//! 2:4 semi-structured sparsity substrate.
//!
//! Everything the paper's FST (fully sparse training) scheme needs, in
//! dependency order: masks and magnitude pruning ([`mask`]), the
//! transposable-mask search of §5.1 ([`transposable`]) and its
//! 2-approximation baseline ([`two_approx`]), the MVUE gradient estimator
//! ([`mvue`]), flip-rate instrumentation of §4.1 ([`flip`]), and the CPU
//! compute substrate standing in for sparse tensor cores: the tiled +
//! threaded kernel backend ([`kernels`]) behind the dense GEMM entry
//! points ([`gemm`]) and the compressed 2:4 spMM ([`spmm`]), gated activations
//! ([`geglu`]), and full FFN / transformer-block workloads ([`ffn`],
//! [`block`]) for the Fig. 7 / Table 11/13 reproductions.
//!
//! Two operand families consume the 2:4 machinery, selected by
//! [`SparseMode`]: the paper's *weight* sparsity (transposable masks,
//! compressed-stationary weights, MVUE gradient spMMs) and *activation*
//! sparsity in the style of the Haziza et al. follow-on, where the
//! post-GEGLU activation is magnitude-pruned 2:4 per token and streamed
//! compressed-stationary through the second FFN matmul. `Both` stacks
//! the two. See [`ffn`] for the per-mode kernel pipelines.

pub mod block;
pub mod ffn;
pub mod flip;
pub mod geglu;
pub mod gemm;
pub mod kernels;
pub mod mask;
pub mod mvue;
pub mod spmm;
pub mod transposable;
pub mod two_approx;
pub mod workloads;

pub use kernels::{KernelBackend, Scratch};
pub use mask::{prune24, prune24_mask, Mask};
pub use transposable::transposable_mask;

/// Which FFN operand the 2:4 machinery prunes — the `[sparse] mode`
/// config key / `--sparse-mode` CLI flag.
///
/// * `Weight` — the source paper's FST regime: transposable weight
///   masks, compressed-stationary weights, MVUE gradient spMMs. The
///   default, and byte-identical to the pre-mode pipeline.
/// * `Activation` — weights stay dense; the post-GEGLU activation is
///   2:4-pruned per token (each group of four consecutive hidden
///   lanes keeps its top-2 magnitude pair), packed via
///   [`spmm::Compressed24`], and driven compressed-stationary through
///   the second FFN matmul. The backward is straight-through:
///   gradients flow only to the surviving lanes.
/// * `Both` — compressed weights AND pruned activations. The weight
///   operand keeps the compressed-stationary slot (the CPU spMM, like
///   sparse tensor cores, structures only one operand), so the pruned
///   activation streams through dense with its lanes zeroed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseMode {
    Weight,
    Activation,
    Both,
}

impl SparseMode {
    /// Parse the config/CLI spelling (`weight` / `activation` / `both`).
    pub fn parse(s: &str) -> Option<SparseMode> {
        match s {
            "weight" => Some(SparseMode::Weight),
            "activation" => Some(SparseMode::Activation),
            "both" => Some(SparseMode::Both),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SparseMode::Weight => "weight",
            SparseMode::Activation => "activation",
            SparseMode::Both => "both",
        }
    }

    /// Does this mode compress/mask the FFN weights?
    pub fn sparse_weights(self) -> bool {
        !matches!(self, SparseMode::Activation)
    }

    /// Does this mode 2:4-prune the post-GEGLU activations?
    pub fn sparse_activations(self) -> bool {
        !matches!(self, SparseMode::Weight)
    }
}

impl std::fmt::Display for SparseMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}
