//! 2:4 semi-structured sparsity substrate.
//!
//! Everything the paper's FST (fully sparse training) scheme needs, in
//! dependency order: masks and magnitude pruning ([`mask`]), the
//! transposable-mask search of §5.1 ([`transposable`]) and its
//! 2-approximation baseline ([`two_approx`]), the MVUE gradient estimator
//! ([`mvue`]), flip-rate instrumentation of §4.1 ([`flip`]), and the CPU
//! compute substrate standing in for sparse tensor cores: the tiled +
//! threaded kernel backend ([`kernels`]) behind the dense GEMM entry
//! points ([`gemm`]) and the compressed 2:4 spMM ([`spmm`]), gated activations
//! ([`geglu`]), and full FFN / transformer-block workloads ([`ffn`],
//! [`block`]) for the Fig. 7 / Table 11/13 reproductions.

pub mod block;
pub mod ffn;
pub mod flip;
pub mod geglu;
pub mod gemm;
pub mod kernels;
pub mod mask;
pub mod mvue;
pub mod spmm;
pub mod transposable;
pub mod two_approx;
pub mod workloads;

pub use kernels::{KernelBackend, Scratch};
pub use mask::{prune24, prune24_mask, Mask};
pub use transposable::transposable_mask;
