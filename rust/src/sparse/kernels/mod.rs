//! Kernel backend: tiled, multi-threaded GEMM/spMM with a scratch arena.
//!
//! This module is the CPU substrate's answer to the paper's sparse
//! tensor cores. The paper's speedup claim (Fig. 7, Tables 11/13) is
//! that the three FFN GEMMs of Eq. 2-4 run at ~2x when one operand is
//! 2:4-compressed, because the hardware performs q/2 MACs per output
//! element instead of q. For that claim to be measurable here, both the
//! dense baseline and the spMM must run at machine speed — otherwise the
//! benches measure allocator traffic and cache thrash instead of the
//! q/2-MAC structure. The backend therefore provides:
//!
//! * [`threading`] — a persistent, work-stealing-free thread pool that
//!   partitions *output rows* in microkernel-aligned blocks
//!   (`PALLAS_NUM_THREADS` env, `[kernels] threads` config,
//!   [`set_num_threads`]). Row ownership + fixed per-row instruction
//!   sequences make results bitwise identical across thread counts.
//! * [`tiled`] — cache-blocked, register-tiled `std::simd` kernels. The
//!   dense GEMMs use 4x2 (dot-form, `gemm_nt`) and 4x16 (AXPY-form,
//!   `gemm_nn`/`gemm_tn`) register tiles: the microkernel is the CPU
//!   analogue of the tensor-core MMA tile, with the k-loop playing the
//!   role of the MMA's depth dimension. The spMMs make the compressed
//!   operand stationary and stream the dense operand along the token
//!   dimension so the 2-bit metadata turns into a row offset — exactly
//!   how the sparse tensor core's operand muxing consumes (values,
//!   metadata) without ever materializing the dense matrix. The sparse
//!   kernels execute half the FMA work of their dense twins at equal
//!   tiling and thread count, which is the paper's Eq. 2-4 arithmetic.
//!   The `_cm` variants additionally keep the output column-major
//!   (paper Table 12) and/or accept a column-major activation in place,
//!   deleting the epilogue scatter and the staging transposes the
//!   row-major forms pay — the sparse FFN pipeline runs entirely on
//!   them between its row-major block boundaries.
//! * [`naive`] — the seed's single-threaded reference kernels, kept as
//!   the differential-test oracle ([`KernelBackend::Naive`]) and used
//!   for problems too small to amortize threading/tiling overhead.
//! * [`scratch`] — a checkout/checkin buffer arena so steady-state
//!   forward/backward/recompress paths allocate nothing.
//!
//! Backend selection: `PALLAS_KERNEL_BACKEND=naive|tiled` env (default
//! tiled), [`set_backend`] at runtime, `[kernels] backend` in configs.

pub mod naive;
pub mod scratch;
pub mod threading;
pub mod tiled;

use std::sync::atomic::{AtomicU8, Ordering};

pub use scratch::{with_thread_scratch, Scratch};
pub use threading::{num_threads, parallel_chunks, parallel_rows, set_num_threads};

use crate::obs::{kernel_scope, KernelFamily};
use crate::sparse::spmm::Compressed24;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Seed reference kernels: single-threaded, no tiling.
    Naive,
    /// Tiled + threaded `std::simd` kernels (default).
    Tiled,
}

/// 0 = unresolved, 1 = naive, 2 = tiled.
static BACKEND: AtomicU8 = AtomicU8::new(0);

/// Currently selected backend (resolves `PALLAS_KERNEL_BACKEND` once).
pub fn backend() -> KernelBackend {
    match BACKEND.load(Ordering::Relaxed) {
        1 => KernelBackend::Naive,
        2 => KernelBackend::Tiled,
        _ => {
            let b = match std::env::var("PALLAS_KERNEL_BACKEND").ok().as_deref() {
                Some("naive") => KernelBackend::Naive,
                _ => KernelBackend::Tiled,
            };
            set_backend(b);
            b
        }
    }
}

pub fn set_backend(b: KernelBackend) {
    let v = match b {
        KernelBackend::Naive => 1,
        KernelBackend::Tiled => 2,
    };
    BACKEND.store(v, Ordering::Relaxed);
}

/// Label for reports/bench records.
pub fn backend_name() -> &'static str {
    match backend() {
        KernelBackend::Naive => "naive",
        KernelBackend::Tiled => "tiled",
    }
}

/// Parse a config/CLI backend name; `"auto"` keeps the current choice.
pub fn set_backend_by_name(name: &str) -> bool {
    match name {
        "naive" => set_backend(KernelBackend::Naive),
        "tiled" => set_backend(KernelBackend::Tiled),
        "auto" | "" => {}
        _ => return false,
    }
    true
}

/// Below this many FLOPs the tiled path cannot amortize pool wakeup and
/// operand staging; dispatch falls back to the naive kernels.
const TILED_MIN_FLOPS: usize = 1 << 18;

#[inline]
fn tiled_pays_off(flops: usize) -> bool {
    backend() == KernelBackend::Tiled && flops >= TILED_MIN_FLOPS
}

// --- dispatched entry points (the public gemm/spmm functions call these) ---
//
// The output-length asserts are load-bearing: the tiled backend writes
// through raw pointers with only debug-level bounds checks, so an
// undersized output must be rejected here, in release builds too.
//
// Each entry point opens an `obs::kernel_scope` — per-family time
// accounting lives HERE, at the dispatch layer, never inside
// `threading`/`tiled`: the pool's row partitioning and per-row
// instruction sequences are untouched, so the bitwise thread-count
// invariance of the numerics is preserved. Below Level::Metrics the
// scope is a single relaxed load (no clock read).

pub fn gemm_nt_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::GemmNt);
    let (p, q) = a.dims2();
    let (r, _) = b.dims2();
    assert_eq!(c.data.len(), p * r, "gemm_nt_into: output len");
    if tiled_pays_off(2 * p * q * r) {
        tiled::gemm_nt_into(a, b, c)
    } else {
        naive::gemm_nt_into(a, b, c)
    }
}

pub fn gemm_nn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::GemmNn);
    let (p, r) = a.dims2();
    let (_, q) = b.dims2();
    assert_eq!(c.data.len(), p * q, "gemm_nn_into: output len");
    if tiled_pays_off(2 * p * q * r) {
        tiled::gemm_nn_into(a, b, c)
    } else {
        naive::gemm_nn_into(a, b, c)
    }
}

pub fn gemm_tn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::GemmTn);
    let (p, r) = a.dims2();
    let (_, q) = b.dims2();
    assert_eq!(c.data.len(), r * q, "gemm_tn_into: output len");
    if tiled_pays_off(2 * p * q * r) {
        tiled::gemm_tn_into(a, b, c)
    } else {
        naive::gemm_tn_into(a, b, c)
    }
}

pub fn spmm_nt_into(x: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmNt);
    let (p, q) = x.dims2();
    assert_eq!(c.data.len(), p * wc.rows, "spmm_nt_into: output len");
    if tiled_pays_off(p * q * wc.rows) {
        tiled::spmm_nt_into(x, wc, c)
    } else {
        naive::spmm_nt_into(x, wc, c)
    }
}

pub fn spmm_nn_into(g: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmNn);
    let (p, r) = g.dims2();
    assert_eq!(c.data.len(), p * wc.cols, "spmm_nn_into: output len");
    if tiled_pays_off(p * r * wc.cols) {
        tiled::spmm_nn_into(g, wc, c)
    } else {
        naive::spmm_nn_into(g, wc, c)
    }
}

pub fn spmm_tn_into(gc: &Compressed24, x: &Tensor, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmTn);
    let (p, q) = x.dims2();
    assert_eq!(c.data.len(), gc.rows * q, "spmm_tn_into: output len");
    if tiled_pays_off(gc.rows * p * q) {
        tiled::spmm_tn_into(gc, x, c)
    } else {
        naive::spmm_tn_into(gc, x, c)
    }
}

// --- column-major (Table 12) epilogue variants -----------------------------
//
// Same dispatch rule and the same load-bearing output-length asserts as
// the row-major entry points; `ct`/`xt` arguments are transposed-shape
// tensors ((cols, tokens) row-major — i.e. the matrix column-major).

/// C = X Wc^T, C left column-major: `ct` is C^T (wc.rows, p).
pub fn spmm_nt_cm_into(x: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmNtCm);
    let (p, q) = x.dims2();
    assert_eq!(q, wc.cols, "spmm_nt_cm_into: inner dim");
    assert_eq!(ct.data.len(), p * wc.rows, "spmm_nt_cm_into: output len");
    if tiled_pays_off(p * q * wc.rows) {
        tiled::spmm_nt_cm_into(x, wc, ct)
    } else {
        naive::spmm_nt_cm_into(x, wc, ct)
    }
}

/// C = X Wc^T from a pre-transposed `xt` = X^T (q, p); C (p, wc.rows)
/// row-major (the column-major -> row-major boundary form).
pub fn spmm_nt_t_into(xt: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmNtT);
    let (q, p) = xt.dims2();
    assert_eq!(q, wc.cols, "spmm_nt_t_into: inner dim");
    assert_eq!(c.data.len(), p * wc.rows, "spmm_nt_t_into: output len");
    if tiled_pays_off(p * q * wc.rows) {
        tiled::spmm_nt_t_into(xt, wc, c)
    } else {
        naive::spmm_nt_t_into(xt, wc, c)
    }
}

/// C = X Wc^T, pre-transposed input AND column-major output: the fully
/// fused interior form (`xt` = X^T (q, p), `ct` = C^T (wc.rows, p)).
pub fn spmm_nt_tcm_into(xt: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmNtTcm);
    let (q, p) = xt.dims2();
    assert_eq!(q, wc.cols, "spmm_nt_tcm_into: inner dim");
    assert_eq!(ct.data.len(), p * wc.rows, "spmm_nt_tcm_into: output len");
    if tiled_pays_off(p * q * wc.rows) {
        tiled::spmm_nt_tcm_into(xt, wc, ct)
    } else {
        naive::spmm_nt_tcm_into(xt, wc, ct)
    }
}

/// C = G Wc, everything column-major: `gt` = G^T (wc.rows, p), `ct` =
/// C^T (wc.cols, p). Zero scratch staging (see [`tiled::spmm_nn_cm_into`]).
pub fn spmm_nn_cm_into(gt: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmNnCm);
    let (r, p) = gt.dims2();
    assert_eq!(r, wc.rows, "spmm_nn_cm_into: inner dim");
    assert_eq!(ct.data.len(), p * wc.cols, "spmm_nn_cm_into: output len");
    if tiled_pays_off(p * r * wc.cols) {
        tiled::spmm_nn_cm_into(gt, wc, ct)
    } else {
        naive::spmm_nn_cm_into(gt, wc, ct)
    }
}

/// C = Gc^T X with X given column-major (`xt` = X^T (q, p)); C
/// (gc.rows, q) row-major.
pub fn spmm_tn_cm_into(gc: &Compressed24, xt: &Tensor, c: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::SpmmTnCm);
    let (q, p) = xt.dims2();
    assert_eq!(p, gc.cols, "spmm_tn_cm_into: reduction dim");
    assert_eq!(c.data.len(), gc.rows * q, "spmm_tn_cm_into: output len");
    if tiled_pays_off(gc.rows * p * q) {
        tiled::spmm_tn_cm_into(gc, xt, c)
    } else {
        naive::spmm_tn_cm_into(gc, xt, c)
    }
}

/// Parallel transpose through the kernel pool — the hot-path variant of
/// [`Tensor::transpose_into`] (which stays sequential for cold paths).
pub fn transpose(src: &Tensor, out: &mut Tensor) {
    let _k = kernel_scope(KernelFamily::Transpose);
    let (r, c) = src.dims2();
    out.resize_to(&[c, r]);
    tiled::transpose_into_buf(&src.data, r, c, &mut out.data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        Tensor::normal(shape, 0.5, &mut Rng::new(seed))
    }

    // Differential tests across backends live in
    // rust/tests/kernels_differential.rs; here we only pin dispatch
    // plumbing (global-state mutation kept inside a single #[test] so
    // parallel test threads don't race on the backend selector).
    #[test]
    fn backend_selection_and_dispatch() {
        let prev = backend();
        set_backend(KernelBackend::Naive);
        assert_eq!(backend(), KernelBackend::Naive);
        let a = rand(&[5, 12], 0);
        let b = rand(&[7, 12], 1);
        let mut c1 = Tensor::zeros(&[5, 7]);
        gemm_nt_into(&a, &b, &mut c1);
        set_backend(KernelBackend::Tiled);
        assert_eq!(backend(), KernelBackend::Tiled);
        let mut c2 = Tensor::zeros(&[5, 7]);
        gemm_nt_into(&a, &b, &mut c2);
        assert!(c1.max_abs_diff(&c2) < 1e-5);
        assert!(set_backend_by_name("auto"));
        assert!(!set_backend_by_name("gpu"));
        set_backend(prev);
    }

    #[test]
    fn tiled_direct_matches_naive_on_unaligned_shape() {
        // (13, 20, 9): not multiples of any tile size
        let a = rand(&[13, 20], 2);
        let b = rand(&[9, 20], 3);
        let mut cn = Tensor::zeros(&[13, 9]);
        naive::gemm_nt_into(&a, &b, &mut cn);
        let mut ct = Tensor::zeros(&[13, 9]);
        tiled::gemm_nt_into(&a, &b, &mut ct);
        assert!(cn.max_abs_diff(&ct) < 1e-4);
    }
}
