//! Cache-blocked, register-tiled, multi-threaded kernels (`std::simd`).
//!
//! Dense GEMMs use classic register-tiled microkernels:
//! * `gemm_nt` — both operands are k-contiguous, so the microkernel is a
//!   4x2 block of SIMD dot products sharing A-row loads (14 vector ops
//!   per 64 MACs vs ~3.5 per 8 for a per-element dot).
//! * `gemm_nn` / `gemm_tn` — AXPY-structured: a 4x16 register tile
//!   accumulates broadcast(A) * vector(B) over the reduction dimension.
//!
//! The 2:4 spMMs avoid gathers entirely by making the *compressed*
//! operand stationary and streaming the dense operand along the token
//! dimension: with X transposed (one O(pq) pass, amortized over O(pqr/2)
//! MACs), the kept value's absolute column index becomes a row offset
//! into X^T and every load is contiguous — the CPU analogue of the
//! sparse tensor core consuming (values, 2-bit metadata) directly. An
//! in-register select over the 4-candidate group was evaluated and
//! rejected: on CPU the 2-level select tree costs more shuffle uops than
//! the q/2 MACs it saves, while the transposed streaming form does q/2
//! FMAs with zero shuffles and wins against the tiled dense kernel
//! (see BENCH_kernels.json).
//!
//! Column-major epilogues (paper Appendix A.2, Table 12): the `_cm`
//! spMM variants keep the output in column-major — the layout the next
//! op in the sparse FFN wants — instead of undoing it. Because the
//! token dimension is the SIMD dimension, a column-major store is a
//! contiguous 8-lane store where the row-major epilogue scatters; and a
//! column-major *input* (an activation the previous `_cm` op produced)
//! is exactly the transposed operand the streaming form needs, so the
//! per-call staging transpose disappears too. `spmm_nn_cm_into` is the
//! extreme case: both of `spmm_nn_into`'s O(pq) scratch transposes
//! (G^T in, C^T out) vanish and the kernel takes nothing from the
//! arena. The `nt`/`nn` `_cm` kernels run the exact per-element
//! accumulation sequence of their row-major twins (only the stores
//! differ), so swapping the layout never changes a float there;
//! `spmm_tn_cm_into` is a genuinely different (gather-dot) reduction
//! and matches its twin to rounding, not bitwise.
//!
//! Determinism: work is partitioned over *output rows* in microkernel-
//! aligned blocks ([`threading::parallel_chunks`]), and every output
//! element's accumulation sequence is independent of both the thread
//! count and the block a row lands in — results are bitwise identical
//! for any `PALLAS_NUM_THREADS` (asserted by the differential tests).

use std::simd::prelude::*;
use std::simd::StdFloat;

use super::scratch::with_thread_scratch;
use super::threading::{parallel_chunks, MutPtr};
use crate::sparse::gemm::{axpy, dot};
use crate::sparse::spmm::Compressed24;
use crate::tensor::Tensor;

const L: usize = 8;
type F = Simd<f32, L>;

/// Microkernel height (rows per register tile); also the row-partition
/// unit for the dense kernels.
const MR: usize = 4;
/// Column pair for the `gemm_nt` dot microkernel.
const NR: usize = 2;
/// Column panel (two vectors) for the AXPY microkernels.
const NC: usize = 2 * L;

// ---------------------------------------------------------------------------
// dense GEMM
// ---------------------------------------------------------------------------

/// C = A B^T. A: (p,q), B: (r,q) -> C: (p,r).
pub fn gemm_nt_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, q) = a.dims2();
    let (r, qb) = b.dims2();
    debug_assert_eq!(q, qb);
    debug_assert_eq!(c.data.len(), p * r);
    let ad = &a.data[..];
    let bd = &b.data[..];
    let out = MutPtr::new(&mut c.data);
    parallel_chunks(p, MR, 4, &|i0, i1| {
        let cs = unsafe { out.range(i0 * r, i1 * r) };
        nt_rows(&ad[i0 * q..i1 * q], bd, cs, i1 - i0, q, r);
    });
}

fn nt_rows(a: &[f32], b: &[f32], c: &mut [f32], rows: usize, q: usize, r: usize) {
    let full_j = r - r % NR;
    let full_i = rows - rows % MR;
    let mut j = 0;
    while j < full_j {
        let b0 = &b[j * q..j * q + q];
        let b1 = &b[(j + 1) * q..(j + 1) * q + q];
        let mut i = 0;
        while i < full_i {
            micro_nt(a, i, q, b0, b1, c, j, r);
            i += MR;
        }
        for it in full_i..rows {
            let arow = &a[it * q..it * q + q];
            c[it * r + j] = dot(arow, b0);
            c[it * r + j + 1] = dot(arow, b1);
        }
        j += NR;
    }
    if full_j < r {
        let b0 = &b[full_j * q..full_j * q + q];
        for it in 0..rows {
            c[it * r + full_j] = dot(&a[it * q..it * q + q], b0);
        }
    }
}

/// 4 rows x 2 cols of dot products; A-row loads shared across the pair.
#[inline(always)]
fn micro_nt(
    a: &[f32],
    i: usize,
    q: usize,
    b0: &[f32],
    b1: &[f32],
    c: &mut [f32],
    j: usize,
    r: usize,
) {
    let mut acc = [[F::splat(0.0); NR]; MR];
    let kb = q / L;
    for t in 0..kb {
        let o = t * L;
        let bv0 = F::from_slice(&b0[o..o + L]);
        let bv1 = F::from_slice(&b1[o..o + L]);
        for m in 0..MR {
            let av = F::from_slice(&a[(i + m) * q + o..(i + m) * q + o + L]);
            acc[m][0] = av.mul_add(bv0, acc[m][0]);
            acc[m][1] = av.mul_add(bv1, acc[m][1]);
        }
    }
    let mut tail = [[0f32; NR]; MR];
    for k in kb * L..q {
        for m in 0..MR {
            let av = a[(i + m) * q + k];
            tail[m][0] += av * b0[k];
            tail[m][1] += av * b1[k];
        }
    }
    for m in 0..MR {
        c[(i + m) * r + j] = acc[m][0].reduce_sum() + tail[m][0];
        c[(i + m) * r + j + 1] = acc[m][1].reduce_sum() + tail[m][1];
    }
}

/// C = A B. A: (p,r), B: (r,q) -> C: (p,q).
pub fn gemm_nn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, r) = a.dims2();
    let (rb, q) = b.dims2();
    debug_assert_eq!(r, rb);
    debug_assert_eq!(c.data.len(), p * q);
    let ad = &a.data[..];
    let bd = &b.data[..];
    let out = MutPtr::new(&mut c.data);
    parallel_chunks(p, MR, 4, &|i0, i1| {
        let cs = unsafe { out.range(i0 * q, i1 * q) };
        nn_rows(&ad[i0 * r..i1 * r], bd, cs, i1 - i0, r, q);
    });
}

fn nn_rows(a: &[f32], b: &[f32], c: &mut [f32], rows: usize, r: usize, q: usize) {
    c.fill(0.0);
    let full_i = rows - rows % MR;
    let full_j = q - q % NC;
    let mut i = 0;
    while i < full_i {
        let mut j = 0;
        while j < full_j {
            // reduction over k: alpha(m, s) = a[(i+m)*r + s]
            micro_axpy(a, i * r, r, 1, r, b, j, q, c, i, q);
            j += NC;
        }
        i += MR;
    }
    if full_j < q {
        for i in 0..full_i {
            let crow = &mut c[i * q + full_j..i * q + q];
            for k in 0..r {
                axpy(a[i * r + k], &b[k * q + full_j..k * q + q], crow);
            }
        }
    }
    for i in full_i..rows {
        let crow = &mut c[i * q..(i + 1) * q];
        for k in 0..r {
            axpy(a[i * r + k], &b[k * q..(k + 1) * q], crow);
        }
    }
}

/// C = A^T B. A: (p,r), B: (p,q) -> C: (r,q). Partitioned over C rows.
pub fn gemm_tn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, r) = a.dims2();
    let (pb, q) = b.dims2();
    debug_assert_eq!(p, pb);
    debug_assert_eq!(c.data.len(), r * q);
    let ad = &a.data[..];
    let bd = &b.data[..];
    let out = MutPtr::new(&mut c.data);
    parallel_chunks(r, MR, 4, &|k0, k1| {
        let cs = unsafe { out.range(k0 * q, k1 * q) };
        tn_rows(ad, bd, cs, k0, k1 - k0, p, r, q);
    });
}

fn tn_rows(a: &[f32], b: &[f32], c: &mut [f32], k0: usize, rows: usize, p: usize, r: usize, q: usize) {
    c.fill(0.0);
    let full_k = rows - rows % MR;
    let full_j = q - q % NC;
    let mut kk = 0;
    while kk < full_k {
        let mut j = 0;
        while j < full_j {
            // reduction over i: alpha(m, s) = a[s*r + k0 + kk + m]
            micro_axpy(a, k0 + kk, 1, r, p, b, j, q, c, kk, q);
            j += NC;
        }
        kk += MR;
    }
    if full_j < q {
        for kk in 0..full_k {
            let crow = &mut c[kk * q + full_j..kk * q + q];
            for i in 0..p {
                axpy(a[i * r + k0 + kk], &b[i * q + full_j..i * q + q], crow);
            }
        }
    }
    for kk in full_k..rows {
        let crow = &mut c[kk * q..(kk + 1) * q];
        for i in 0..p {
            axpy(a[i * r + k0 + kk], &b[i * q..(i + 1) * q], crow);
        }
    }
}

/// Shared 4x16 AXPY-structured register tile.
///
/// Computes `C[crow0+m][j..j+16] = sum_s alpha(m, s) * B[s][j..j+16]` for
/// m in 0..4, where `alpha(m, s) = a[a_base + m*a_row_stride + s*a_step]`
/// and the reduction runs `s in 0..steps` over rows of `b` (row stride
/// `q`). `gemm_nn` instantiates it with A walked along a row
/// (`a_row_stride = r`, `a_step = 1`, `steps = r`); `gemm_tn` with A
/// walked down a column (`a_row_stride = 1`, `a_step = r`, `steps = p`).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_axpy(
    a: &[f32],
    a_base: usize,
    a_row_stride: usize,
    a_step: usize,
    steps: usize,
    b: &[f32],
    j: usize,
    q: usize,
    c: &mut [f32],
    crow0: usize,
    c_stride: usize,
) {
    let mut acc = [[F::splat(0.0); 2]; MR];
    for s in 0..steps {
        let bo = s * q + j;
        let bv0 = F::from_slice(&b[bo..bo + L]);
        let bv1 = F::from_slice(&b[bo + L..bo + 2 * L]);
        for m in 0..MR {
            let av = F::splat(a[a_base + m * a_row_stride + s * a_step]);
            acc[m][0] = av.mul_add(bv0, acc[m][0]);
            acc[m][1] = av.mul_add(bv1, acc[m][1]);
        }
    }
    for m in 0..MR {
        let o = (crow0 + m) * c_stride + j;
        acc[m][0].copy_to_slice(&mut c[o..o + L]);
        acc[m][1].copy_to_slice(&mut c[o + L..o + 2 * L]);
    }
}

// ---------------------------------------------------------------------------
// 2:4 spMM
// ---------------------------------------------------------------------------

/// Row-partition unit for the spMM kernels (one SIMD vector of outputs).
const IB: usize = L;

/// C = X Wc^T. X: (p,q), Wc: (r,q) 2:4-compressed -> C: (p,r).
///
/// Compressed-stationary form: stream X^T along the token dimension so
/// the metadata index selects a *row* of X^T and every load is
/// contiguous — q/2 FMAs per 8..16 outputs, no gathers, no selects.
pub fn spmm_nt_into(x: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (p, q) = x.dims2();
    debug_assert_eq!(q, wc.cols);
    let r = wc.rows;
    let half = q / 2;
    debug_assert_eq!(c.data.len(), p * r);
    let mut xt = with_thread_scratch(|s| s.take_vec(q * p));
    transpose_into_buf(&x.data, p, q, &mut xt);
    {
        let xt_ref = &xt[..];
        let vals = &wc.values[..];
        let aidx = &wc.abs_indices[..];
        let out = MutPtr::new(&mut c.data);
        parallel_chunks(p, IB, 4, &|i0, i1| {
            let cs = unsafe { out.range(i0 * r, i1 * r) };
            spmm_nt_range(xt_ref, vals, aidx, cs, i0, i1, p, r, half);
        });
    }
    with_thread_scratch(|s| s.give_vec(xt));
}

fn spmm_nt_range(
    xt: &[f32],
    vals: &[f32],
    aidx: &[u32],
    cs: &mut [f32],
    i0: usize,
    i1: usize,
    p: usize,
    r: usize,
    half: usize,
) {
    let n = i1 - i0;
    let full16 = n - n % (2 * L);
    let full8 = n - n % L;
    for j in 0..r {
        let v = &vals[j * half..(j + 1) * half];
        let ix = &aidx[j * half..(j + 1) * half];
        let mut ib = 0;
        // 16 outputs per pass: two vectors sharing the value broadcasts,
        // even/odd-h accumulator chains for ILP.
        while ib < full16 {
            let base = i0 + ib;
            let (mut e0, mut o0) = (F::splat(0.0), F::splat(0.0));
            let (mut e1, mut o1) = (F::splat(0.0), F::splat(0.0));
            let mut h = 0;
            while h + 2 <= half {
                let ce = ix[h] as usize * p + base;
                let co = ix[h + 1] as usize * p + base;
                let ve = F::splat(v[h]);
                let vo = F::splat(v[h + 1]);
                e0 = ve.mul_add(F::from_slice(&xt[ce..ce + L]), e0);
                e1 = ve.mul_add(F::from_slice(&xt[ce + L..ce + 2 * L]), e1);
                o0 = vo.mul_add(F::from_slice(&xt[co..co + L]), o0);
                o1 = vo.mul_add(F::from_slice(&xt[co + L..co + 2 * L]), o1);
                h += 2;
            }
            if h < half {
                let ce = ix[h] as usize * p + base;
                let ve = F::splat(v[h]);
                e0 = ve.mul_add(F::from_slice(&xt[ce..ce + L]), e0);
                e1 = ve.mul_add(F::from_slice(&xt[ce + L..ce + 2 * L]), e1);
            }
            scatter_col(e0 + o0, cs, ib * r + j, r);
            scatter_col(e1 + o1, cs, (ib + L) * r + j, r);
            ib += 2 * L;
        }
        // one 8-wide block (identical per-lane arithmetic)
        while ib < full8 {
            let base = i0 + ib;
            let (mut e0, mut o0) = (F::splat(0.0), F::splat(0.0));
            let mut h = 0;
            while h + 2 <= half {
                let ce = ix[h] as usize * p + base;
                let co = ix[h + 1] as usize * p + base;
                e0 = F::splat(v[h]).mul_add(F::from_slice(&xt[ce..ce + L]), e0);
                o0 = F::splat(v[h + 1]).mul_add(F::from_slice(&xt[co..co + L]), o0);
                h += 2;
            }
            if h < half {
                let ce = ix[h] as usize * p + base;
                e0 = F::splat(v[h]).mul_add(F::from_slice(&xt[ce..ce + L]), e0);
            }
            scatter_col(e0 + o0, cs, ib * r + j, r);
            ib += L;
        }
        // scalar tail rows (globally fixed: partition unit is 8)
        for it in full8..n {
            let i = i0 + it;
            let (mut se, mut so) = (0f32, 0f32);
            let mut h = 0;
            while h + 2 <= half {
                se = v[h].mul_add(xt[ix[h] as usize * p + i], se);
                so = v[h + 1].mul_add(xt[ix[h + 1] as usize * p + i], so);
                h += 2;
            }
            if h < half {
                se = v[h].mul_add(xt[ix[h] as usize * p + i], se);
            }
            cs[it * r + j] = se + so;
        }
    }
}

/// Write one 8-lane accumulator down a column of a row-major block.
#[inline(always)]
fn scatter_col(v: F, c: &mut [f32], start: usize, stride: usize) {
    let arr = v.to_array();
    for (l, &val) in arr.iter().enumerate() {
        c[start + l * stride] = val;
    }
}

/// C = G Wc (dense-equivalent (r,q)). G: (p,r) -> C: (p,q).
///
/// Same compressed-stationary idea as `spmm_nt`, on the output side: the
/// scatter index selects a row of C^T, so the update is a contiguous
/// broadcast-AXPY along the token dimension. G^T and C^T live in the
/// per-thread scratch arena; the final transpose-out is O(pq).
pub fn spmm_nn_into(g: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (p, r) = g.dims2();
    debug_assert_eq!(r, wc.rows);
    let q = wc.cols;
    let half = q / 2;
    debug_assert_eq!(c.data.len(), p * q);
    let (mut gt, mut ct) = with_thread_scratch(|s| {
        let gt = s.take_vec(r * p);
        let ct = s.take_vec(q * p);
        (gt, ct)
    });
    transpose_into_buf(&g.data, p, r, &mut gt);
    {
        let gt_ref = &gt[..];
        let vals = &wc.values[..];
        let aidx = &wc.abs_indices[..];
        let ctp = MutPtr::new(&mut ct);
        let out = MutPtr::new(&mut c.data);
        parallel_chunks(p, IB, 4, &|i0, i1| {
            let n = i1 - i0;
            // zero this thread's C^T columns
            for cq in 0..q {
                unsafe { ctp.range(cq * p + i0, cq * p + i1) }.fill(0.0);
            }
            let full8 = n - n % L;
            for k in 0..r {
                let v = &vals[k * half..(k + 1) * half];
                let ix = &aidx[k * half..(k + 1) * half];
                let mut ib = 0;
                while ib < full8 {
                    let base = i0 + ib;
                    let gv = F::from_slice(&gt_ref[k * p + base..k * p + base + L]);
                    for h in 0..half {
                        let cq = ix[h] as usize;
                        let crow = unsafe { ctp.range(cq * p + base, cq * p + base + L) };
                        let cv = F::from_slice(crow);
                        F::splat(v[h]).mul_add(gv, cv).copy_to_slice(crow);
                    }
                    ib += L;
                }
                for it in full8..n {
                    let i = i0 + it;
                    let gi = gt_ref[k * p + i];
                    for h in 0..half {
                        let cq = ix[h] as usize;
                        let cell = unsafe { ctp.range(cq * p + i, cq * p + i + 1) };
                        cell[0] = v[h].mul_add(gi, cell[0]);
                    }
                }
            }
            // transpose out into C rows i0..i1
            let cs = unsafe { out.range(i0 * q, i1 * q) };
            for cq in 0..q {
                let col = unsafe { ctp.range(cq * p + i0, cq * p + i1) };
                for (it, &val) in col.iter().enumerate() {
                    cs[it * q + cq] = val;
                }
            }
        });
    }
    with_thread_scratch(|s| {
        s.give_vec(gt);
        s.give_vec(ct);
    });
}

/// C = Gc^T X. Gc: (r,p) 2:4-compressed along p, X: (p,q) -> C: (r,q).
///
/// Already AXPY-structured in the naive form; here the AXPYs are SIMD,
/// the reduction is blocked so a window of X rows stays cache-hot across
/// a row block of C, and C rows are partitioned across threads.
pub fn spmm_tn_into(gc: &Compressed24, x: &Tensor, c: &mut Tensor) {
    let (p, q) = x.dims2();
    debug_assert_eq!(p, gc.cols);
    let r = gc.rows;
    let half = gc.cols / 2;
    debug_assert_eq!(c.data.len(), r * q);
    // h-block: keeps ~2*HB x-rows (2*HB*q floats) hot across the j block
    const HB: usize = 64;
    let xd = &x.data[..];
    let vals = &gc.values[..];
    let aidx = &gc.abs_indices[..];
    let out = MutPtr::new(&mut c.data);
    parallel_chunks(r, MR, 2, &|j0, j1| {
        let cs = unsafe { out.range(j0 * q, j1 * q) };
        cs.fill(0.0);
        let mut hb = 0;
        while hb < half {
            let he = (hb + HB).min(half);
            for j in j0..j1 {
                let v = &vals[j * half..(j + 1) * half];
                let ix = &aidx[j * half..(j + 1) * half];
                let crow = &mut cs[(j - j0) * q..(j - j0 + 1) * q];
                for h in hb..he {
                    let val = v[h];
                    if val == 0.0 {
                        continue;
                    }
                    let row = ix[h] as usize;
                    axpy(val, &xd[row * q..(row + 1) * q], crow);
                }
            }
            hb += HB;
        }
    });
}

// ---------------------------------------------------------------------------
// 2:4 spMM, column-major (Table 12) epilogues
// ---------------------------------------------------------------------------

/// C = X Wc^T with C left COLUMN-major: `ct` is C^T, (r, p) row-major.
///
/// Same accumulation as [`spmm_nt_into`] — the token dimension is the
/// SIMD dimension — but the epilogue writes each 8-lane accumulator as
/// one contiguous store into a row of C^T instead of scattering it down
/// a column of C. This is the forward FFN GEMM of the paper's Table-12
/// layout: Z comes out column-major, ready for the column-order GEGLU.
pub fn spmm_nt_cm_into(x: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let (p, q) = x.dims2();
    debug_assert_eq!(q, wc.cols);
    let r = wc.rows;
    let half = q / 2;
    debug_assert_eq!(ct.data.len(), p * r);
    let mut xt = with_thread_scratch(|s| s.take_vec(q * p));
    transpose_into_buf(&x.data, p, q, &mut xt);
    {
        let xt_ref = &xt[..];
        let vals = &wc.values[..];
        let aidx = &wc.abs_indices[..];
        let out = MutPtr::new(&mut ct.data);
        parallel_chunks(p, IB, 4, &|i0, i1| {
            spmm_nt_cm_range(xt_ref, vals, aidx, &out, i0, i1, p, r, half);
        });
    }
    with_thread_scratch(|s| s.give_vec(xt));
}

/// [`spmm_nt_cm_into`] with the dense operand ALREADY transposed:
/// `xt` is X^T, (q, p) row-major — e.g. a column-major activation a
/// previous `_cm` op produced. No staging transpose, no scratch.
pub fn spmm_nt_tcm_into(xt: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let (q, p) = xt.dims2();
    debug_assert_eq!(q, wc.cols);
    let r = wc.rows;
    let half = q / 2;
    debug_assert_eq!(ct.data.len(), p * r);
    let xt_ref = &xt.data[..];
    let vals = &wc.values[..];
    let aidx = &wc.abs_indices[..];
    let out = MutPtr::new(&mut ct.data);
    parallel_chunks(p, IB, 4, &|i0, i1| {
        spmm_nt_cm_range(xt_ref, vals, aidx, &out, i0, i1, p, r, half);
    });
}

/// C = X Wc^T with X given pre-transposed (`xt` = X^T, (q, p)) and C
/// row-major — the boundary form: consumes a column-major activation
/// and hands the next (row-major) op its native layout, folding the
/// transpose back into the epilogue scatter instead of a separate pass.
pub fn spmm_nt_t_into(xt: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (q, p) = xt.dims2();
    debug_assert_eq!(q, wc.cols);
    let r = wc.rows;
    let half = q / 2;
    debug_assert_eq!(c.data.len(), p * r);
    let xt_ref = &xt.data[..];
    let vals = &wc.values[..];
    let aidx = &wc.abs_indices[..];
    let out = MutPtr::new(&mut c.data);
    parallel_chunks(p, IB, 4, &|i0, i1| {
        let cs = unsafe { out.range(i0 * r, i1 * r) };
        spmm_nt_range(xt_ref, vals, aidx, cs, i0, i1, p, r, half);
    });
}

/// Inner loop of the column-major `spmm_nt` epilogue: identical
/// accumulation chains to [`spmm_nt_range`], but each 8-lane result is
/// stored contiguously into this thread's `i0..i1` slice of C^T row
/// `j` (disjoint across threads — the partition owns token columns).
fn spmm_nt_cm_range(
    xt: &[f32],
    vals: &[f32],
    aidx: &[u32],
    out: &MutPtr,
    i0: usize,
    i1: usize,
    p: usize,
    r: usize,
    half: usize,
) {
    let n = i1 - i0;
    let full16 = n - n % (2 * L);
    let full8 = n - n % L;
    for j in 0..r {
        let v = &vals[j * half..(j + 1) * half];
        let ix = &aidx[j * half..(j + 1) * half];
        let crow = unsafe { out.range(j * p + i0, j * p + i1) };
        let mut ib = 0;
        while ib < full16 {
            let base = i0 + ib;
            let (mut e0, mut o0) = (F::splat(0.0), F::splat(0.0));
            let (mut e1, mut o1) = (F::splat(0.0), F::splat(0.0));
            let mut h = 0;
            while h + 2 <= half {
                let ce = ix[h] as usize * p + base;
                let co = ix[h + 1] as usize * p + base;
                let ve = F::splat(v[h]);
                let vo = F::splat(v[h + 1]);
                e0 = ve.mul_add(F::from_slice(&xt[ce..ce + L]), e0);
                e1 = ve.mul_add(F::from_slice(&xt[ce + L..ce + 2 * L]), e1);
                o0 = vo.mul_add(F::from_slice(&xt[co..co + L]), o0);
                o1 = vo.mul_add(F::from_slice(&xt[co + L..co + 2 * L]), o1);
                h += 2;
            }
            if h < half {
                let ce = ix[h] as usize * p + base;
                let ve = F::splat(v[h]);
                e0 = ve.mul_add(F::from_slice(&xt[ce..ce + L]), e0);
                e1 = ve.mul_add(F::from_slice(&xt[ce + L..ce + 2 * L]), e1);
            }
            (e0 + o0).copy_to_slice(&mut crow[ib..ib + L]);
            (e1 + o1).copy_to_slice(&mut crow[ib + L..ib + 2 * L]);
            ib += 2 * L;
        }
        while ib < full8 {
            let base = i0 + ib;
            let (mut e0, mut o0) = (F::splat(0.0), F::splat(0.0));
            let mut h = 0;
            while h + 2 <= half {
                let ce = ix[h] as usize * p + base;
                let co = ix[h + 1] as usize * p + base;
                e0 = F::splat(v[h]).mul_add(F::from_slice(&xt[ce..ce + L]), e0);
                o0 = F::splat(v[h + 1]).mul_add(F::from_slice(&xt[co..co + L]), o0);
                h += 2;
            }
            if h < half {
                let ce = ix[h] as usize * p + base;
                e0 = F::splat(v[h]).mul_add(F::from_slice(&xt[ce..ce + L]), e0);
            }
            (e0 + o0).copy_to_slice(&mut crow[ib..ib + L]);
            ib += L;
        }
        for it in full8..n {
            let i = i0 + it;
            let (mut se, mut so) = (0f32, 0f32);
            let mut h = 0;
            while h + 2 <= half {
                se = v[h].mul_add(xt[ix[h] as usize * p + i], se);
                so = v[h + 1].mul_add(xt[ix[h + 1] as usize * p + i], so);
                h += 2;
            }
            if h < half {
                se = v[h].mul_add(xt[ix[h] as usize * p + i], se);
            }
            crow[it] = se + so;
        }
    }
}

/// C = G Wc, everything COLUMN-major: `gt` is G^T (r, p) row-major,
/// `ct` is C^T (q, p) row-major.
///
/// The fused form of [`spmm_nn_into`]: the compressed index addresses a
/// row of C^T, and C^T *is* the output, so both of the row-major
/// kernel's O(pq) scratch transposes (G^T in, C^T out) disappear — the
/// kernel touches no arena buffer at all. Same per-element accumulation
/// order as the staged kernel (k outer, kept-value h inner).
pub fn spmm_nn_cm_into(gt: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let (r, p) = gt.dims2();
    debug_assert_eq!(r, wc.rows);
    let q = wc.cols;
    let half = q / 2;
    debug_assert_eq!(ct.data.len(), p * q);
    let gt_ref = &gt.data[..];
    let vals = &wc.values[..];
    let aidx = &wc.abs_indices[..];
    let ctp = MutPtr::new(&mut ct.data);
    parallel_chunks(p, IB, 4, &|i0, i1| {
        let n = i1 - i0;
        // zero this thread's C^T columns
        for cq in 0..q {
            unsafe { ctp.range(cq * p + i0, cq * p + i1) }.fill(0.0);
        }
        let full8 = n - n % L;
        for k in 0..r {
            let v = &vals[k * half..(k + 1) * half];
            let ix = &aidx[k * half..(k + 1) * half];
            let mut ib = 0;
            while ib < full8 {
                let base = i0 + ib;
                let gv = F::from_slice(&gt_ref[k * p + base..k * p + base + L]);
                for h in 0..half {
                    let cq = ix[h] as usize;
                    let crow = unsafe { ctp.range(cq * p + base, cq * p + base + L) };
                    let cv = F::from_slice(crow);
                    F::splat(v[h]).mul_add(gv, cv).copy_to_slice(crow);
                }
                ib += L;
            }
            for it in full8..n {
                let i = i0 + it;
                let gi = gt_ref[k * p + i];
                for h in 0..half {
                    let cq = ix[h] as usize;
                    let cell = unsafe { ctp.range(cq * p + i, cq * p + i + 1) };
                    cell[0] = v[h].mul_add(gi, cell[0]);
                }
            }
        }
    });
}

/// C = Gc^T X with X given COLUMN-major: Gc: (r, p) 2:4-compressed
/// along p, `xt` = X^T (q, p) row-major -> C: (r, q) row-major.
///
/// The weight-grad sibling for a column-major activation: each output
/// element gathers its p/2 kept X values from ONE contiguous X^T row
/// (8-lane gather + FMA, like the naive `spmm_nt`), so the col-major
/// operand is consumed in place instead of being transposed back.
/// Loop order keeps an X^T row hot across a 4-row block of C.
pub fn spmm_tn_cm_into(gc: &Compressed24, xt: &Tensor, c: &mut Tensor) {
    let (q, p) = xt.dims2();
    debug_assert_eq!(p, gc.cols);
    let r = gc.rows;
    let half = p / 2;
    debug_assert_eq!(c.data.len(), r * q);
    let xd = &xt.data[..];
    let vals = &gc.values[..];
    let aidx = &gc.abs_indices[..];
    let out = MutPtr::new(&mut c.data);
    parallel_chunks(r, MR, 2, &|j0, j1| {
        let cs = unsafe { out.range(j0 * q, j1 * q) };
        let blocks = half / L;
        for k in 0..q {
            let xrow = &xd[k * p..(k + 1) * p];
            for j in j0..j1 {
                let v = &vals[j * half..(j + 1) * half];
                let ix = &aidx[j * half..(j + 1) * half];
                let mut acc = F::splat(0.0);
                for b in 0..blocks {
                    let o = b * L;
                    let idx: Simd<usize, L> =
                        Simd::<u32, L>::from_slice(&ix[o..o + L]).cast();
                    let xs = F::gather_or_default(xrow, idx);
                    acc = F::from_slice(&v[o..o + L]).mul_add(xs, acc);
                }
                let mut s = acc.reduce_sum();
                for o in blocks * L..half {
                    s += v[o] * xrow[ix[o] as usize];
                }
                cs[(j - j0) * q + k] = s;
            }
        }
    });
}

/// Parallel out-of-place transpose: `src` (rows, cols) -> `dst` (cols, rows).
pub(crate) fn transpose_into_buf(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    let dp = MutPtr::new(dst);
    parallel_chunks(cols, L, 16, &|c0, c1| {
        let d = unsafe { dp.range(c0 * rows, c1 * rows) };
        for c in c0..c1 {
            let drow = &mut d[(c - c0) * rows..(c - c0 + 1) * rows];
            for (i, slot) in drow.iter_mut().enumerate() {
                *slot = src[i * cols + c];
            }
        }
    });
}
