//! Zero-allocation scratch arena for kernel and layer temporaries.
//!
//! The seed substrate allocated a fresh `Tensor::zeros` for every GEMM
//! output, every transpose, and every MVUE draw — so the Fig. 7/Table 11
//! benches measured the allocator as much as the arithmetic. [`Scratch`]
//! is a checkout/checkin free-list of `Vec<f32>` buffers (and recycled
//! shape vectors): after one warmup iteration every `take` is served from
//! the free list and the steady state performs no heap allocation.
//!
//! Two usage patterns:
//! * layer code (`DenseFfn::forward_scratch`, …) threads an explicit
//!   `&mut Scratch` through the hot loop;
//! * the tiled kernels need internal temporaries (operand transposes)
//!   even when called through the allocating public API, so they use a
//!   per-thread arena via [`with_thread_scratch`].

use std::cell::RefCell;

use crate::sparse::spmm::Compressed24;
use crate::tensor::Tensor;

#[derive(Default)]
pub struct Scratch {
    /// Free f32 buffers, unordered; best-fit by capacity on `take`.
    bufs: Vec<Vec<f32>>,
    /// Recycled shape vectors (so `take` doesn't allocate a `Vec<usize>`).
    shapes: Vec<Vec<usize>>,
    /// Recycled compressed-operand buffers (MVUE'd gradients).
    comps: Vec<Compressed24>,
    /// Total checkouts served (take_vec/take/take_comp).
    checkouts: u64,
    /// Checkouts that had to heap-allocate because no pooled buffer was
    /// big enough. The serve engine asserts this stays flat across
    /// steady-state decode steps — the "zero allocation" contract.
    fresh: u64,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Number of free buffers currently pooled (tests use this to assert
    /// the steady state stops growing).
    pub fn pooled(&self) -> usize {
        self.bufs.len()
    }

    /// Checkouts served so far.
    pub fn checkouts(&self) -> u64 {
        self.checkouts
    }

    /// Checkouts that heap-allocated (no pooled buffer fit). A steady
    /// state is allocation-free iff this counter stops moving.
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh
    }

    /// Check out a buffer of length `n` with UNSPECIFIED contents (zero
    /// on a fresh allocation, stale on reuse) — takers fully overwrite
    /// or zero it themselves. Best-fit reuse: the smallest pooled buffer
    /// whose capacity covers `n`.
    pub fn take_vec(&mut self, n: usize) -> Vec<f32> {
        self.checkouts += 1;
        let mut best: Option<usize> = None;
        for (i, b) in self.bufs.iter().enumerate() {
            if b.capacity() >= n
                && best.map_or(true, |j| b.capacity() < self.bufs[j].capacity())
            {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                let mut v = self.bufs.swap_remove(i);
                // truncate/extend without touching retained elements:
                // the zero-fill here would be pure memset waste
                if v.len() > n {
                    v.truncate(n);
                } else {
                    v.resize(n, 0.0);
                }
                v
            }
            None => {
                self.fresh += 1;
                vec![0.0; n]
            }
        }
    }

    /// Return a buffer to the pool.
    pub fn give_vec(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.bufs.push(v);
        }
    }

    /// Check out a tensor of the given shape; contents UNSPECIFIED (see
    /// [`Scratch::take_vec`]).
    pub fn take(&mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        let data = self.take_vec(n);
        let mut s = self.shapes.pop().unwrap_or_default();
        s.clear();
        s.extend_from_slice(shape);
        Tensor { shape: s, data }
    }

    /// Return a tensor's storage to the pool.
    pub fn give(&mut self, t: Tensor) {
        self.give_vec(t.data);
        if t.shape.capacity() > 0 {
            self.shapes.push(t.shape);
        }
    }

    /// Check out a compressed-operand buffer (refill it with
    /// `from_masked_into` / `compress_sparse24_into` before use).
    pub fn take_comp(&mut self) -> Compressed24 {
        self.checkouts += 1;
        match self.comps.pop() {
            Some(c) => c,
            None => {
                self.fresh += 1;
                Compressed24::default()
            }
        }
    }

    /// Return a compressed-operand buffer to the pool.
    pub fn give_comp(&mut self, c: Compressed24) {
        self.comps.push(c);
    }
}

thread_local! {
    static THREAD_SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with this thread's kernel-internal arena. Do not call
/// recursively from inside `f` (the kernels never do: temporaries are
/// checked out before any parallel region).
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    THREAD_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_sized_and_fresh_alloc_zeroed() {
        let mut s = Scratch::new();
        let mut v = s.take_vec(16);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|&x| x == 0.0));
        v[3] = 5.0;
        s.give_vec(v);
        // reuse keeps length contract; contents are unspecified
        let v2 = s.take_vec(8);
        assert_eq!(v2.len(), 8);
        s.give_vec(v2);
        let v3 = s.take_vec(12);
        assert_eq!(v3.len(), 12);
    }

    #[test]
    fn reuses_the_same_allocation() {
        let mut s = Scratch::new();
        let v = s.take_vec(1024);
        let p = v.as_ptr();
        s.give_vec(v);
        let v2 = s.take_vec(1000);
        assert_eq!(v2.as_ptr(), p, "smaller request should reuse the pooled buffer");
        s.give_vec(v2);
        assert_eq!(s.pooled(), 1);
    }

    #[test]
    fn best_fit_prefers_smallest_adequate() {
        let mut s = Scratch::new();
        let big = s.take_vec(4096);
        let small = s.take_vec(64);
        let (pb, ps) = (big.as_ptr(), small.as_ptr());
        s.give_vec(big);
        s.give_vec(small);
        assert_eq!(s.take_vec(32).as_ptr(), ps);
        assert_eq!(s.take_vec(2000).as_ptr(), pb);
    }

    #[test]
    fn tensor_roundtrip_recycles_shape() {
        let mut s = Scratch::new();
        let t = s.take(&[3, 5]);
        assert_eq!(t.dims2(), (3, 5));
        assert_eq!(t.len(), 15);
        s.give(t);
        let t2 = s.take(&[5, 3]);
        assert_eq!(t2.shape, vec![5, 3]);
        s.give(t2);
        assert_eq!(s.pooled(), 1);
    }

    #[test]
    fn counters_track_fresh_allocations() {
        let mut s = Scratch::new();
        let v = s.take_vec(64);
        assert_eq!((s.checkouts(), s.fresh_allocs()), (1, 1));
        s.give_vec(v);
        let v = s.take_vec(32); // served from pool
        assert_eq!((s.checkouts(), s.fresh_allocs()), (2, 1));
        s.give_vec(v);
        let v = s.take_vec(1024); // pooled buffer too small
        assert_eq!((s.checkouts(), s.fresh_allocs()), (3, 2));
        s.give_vec(v);
    }

    #[test]
    fn thread_scratch_is_usable() {
        let n = with_thread_scratch(|s| {
            let v = s.take_vec(10);
            let n = v.len();
            s.give_vec(v);
            n
        });
        assert_eq!(n, 10);
    }
}
