//! Reference kernels — the seed's single-threaded implementations.
//!
//! Kept verbatim (modulo the shared SIMD `dot`/`axpy` primitives) as the
//! differential-test oracle for the tiled backend and as the dispatch
//! target for problems too small to amortize tiling/threading overhead.
//! Loop orders make the innermost loop a contiguous dot or AXPY; the
//! spMM inner loops exploit the 2:4 group structure (q/2 MACs per output
//! element instead of q — the sparse-tensor-core arithmetic the paper's
//! speedups come from).

use std::simd::prelude::*;

use crate::sparse::gemm::{axpy, dot};
use crate::sparse::spmm::Compressed24;
use crate::tensor::Tensor;

/// SIMD lane width for the gather kernel (AVX2: 8 x f32).
const LANES: usize = 8;

/// C = A B^T. A: (p,q), B: (r,q) row-major -> C: (p,r).
pub fn gemm_nt_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, q) = a.dims2();
    let (r, _) = b.dims2();
    for i in 0..p {
        let arow = &a.data[i * q..(i + 1) * q];
        let crow = &mut c.data[i * r..(i + 1) * r];
        for j in 0..r {
            let brow = &b.data[j * q..(j + 1) * q];
            crow[j] = dot(arow, brow);
        }
    }
}

/// C = A B. A: (p,r), B: (r,q) row-major -> C: (p,q).
pub fn gemm_nn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, r) = a.dims2();
    let (_, q) = b.dims2();
    c.data.fill(0.0);
    for i in 0..p {
        let crow = &mut c.data[i * q..(i + 1) * q];
        for k in 0..r {
            let aik = a.data[i * r + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * q..(k + 1) * q];
            axpy(aik, brow, crow);
        }
    }
}

/// C = A^T B. A: (p,r), B: (p,q) row-major -> C: (r,q).
pub fn gemm_tn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, r) = a.dims2();
    let (_, q) = b.dims2();
    c.data.fill(0.0);
    for i in 0..p {
        let brow = &b.data[i * q..(i + 1) * q];
        for k in 0..r {
            let aik = a.data[i * r + k];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[k * q..(k + 1) * q];
            axpy(aik, brow, crow);
        }
    }
}

/// C = X Wc^T, Wc row-wise 2:4 compressed. X: (p,q), Wc: (r,q) -> (p,r).
/// q/2 MACs per output element via an 8-lane gather+FMA.
pub fn spmm_nt_into(x: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (p, q) = x.dims2();
    let r = wc.rows;
    let half = q / 2;
    for i in 0..p {
        let xrow = &x.data[i * q..(i + 1) * q];
        let crow = &mut c.data[i * r..(i + 1) * r];
        for j in 0..r {
            crow[j] = spmm_row_dot(wc, j, half, xrow);
        }
    }
}

/// C = G Wc (dense-equivalent W: (r,q)). G: (p,r) -> C: (p,q).
/// Scatter form: q/2 scattered MACs per (row of G, row of W).
pub fn spmm_nn_into(g: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (p, r) = g.dims2();
    let q = wc.cols;
    let half = q / 2;
    c.data.fill(0.0);
    for i in 0..p {
        let grow = &g.data[i * r..(i + 1) * r];
        let crow = &mut c.data[i * q..(i + 1) * q];
        for k in 0..r {
            let gik = grow[k];
            if gik == 0.0 {
                continue;
            }
            let vals = &wc.values[k * half..(k + 1) * half];
            let idxs = &wc.indices[k * half..(k + 1) * half];
            for g4 in 0..q / 4 {
                let dst = &mut crow[g4 * 4..g4 * 4 + 4];
                dst[idxs[g4 * 2] as usize] += gik * vals[g4 * 2];
                dst[idxs[g4 * 2 + 1] as usize] += gik * vals[g4 * 2 + 1];
            }
        }
    }
}

/// C = X Wc^T with C left COLUMN-major (`ct` = C^T, (r, p) row-major).
/// Same gather arithmetic as [`spmm_nt_into`], transposed store —
/// the differential oracle for the tiled `_cm` epilogue.
pub fn spmm_nt_cm_into(x: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let (p, q) = x.dims2();
    let r = wc.rows;
    let half = q / 2;
    for i in 0..p {
        let xrow = &x.data[i * q..(i + 1) * q];
        for j in 0..r {
            ct.data[j * p + i] = spmm_row_dot(wc, j, half, xrow);
        }
    }
}

/// C = X Wc^T with X given pre-transposed (`xt` = X^T, (q, p)), C
/// row-major. Oracle for the boundary form of the tiled kernel.
pub fn spmm_nt_t_into(xt: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (q, p) = xt.dims2();
    debug_assert_eq!(q, wc.cols);
    let r = wc.rows;
    let half = q / 2;
    for i in 0..p {
        for j in 0..r {
            c.data[i * r + j] = spmm_col_dot(wc, j, half, &xt.data, p, i);
        }
    }
}

/// Pre-transposed input AND column-major output: `xt` = X^T (q, p),
/// `ct` = C^T (r, p). Oracle for the fully fused tiled kernel.
pub fn spmm_nt_tcm_into(xt: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let (q, p) = xt.dims2();
    debug_assert_eq!(q, wc.cols);
    let r = wc.rows;
    let half = q / 2;
    for i in 0..p {
        for j in 0..r {
            ct.data[j * p + i] = spmm_col_dot(wc, j, half, &xt.data, p, i);
        }
    }
}

/// q/2 gathered MACs of compressed row `j` against a contiguous
/// activation row (the [`spmm_nt_into`] inner loop, shared).
fn spmm_row_dot(wc: &Compressed24, j: usize, half: usize, xrow: &[f32]) -> f32 {
    let vals = &wc.values[j * half..(j + 1) * half];
    let aidx = &wc.abs_indices[j * half..(j + 1) * half];
    let blocks = half / LANES;
    let mut acc = Simd::<f32, LANES>::splat(0.0);
    for b in 0..blocks {
        let o = b * LANES;
        let idx: Simd<usize, LANES> =
            Simd::<u32, LANES>::from_slice(&aidx[o..o + LANES]).cast();
        let xs = Simd::<f32, LANES>::gather_or_default(xrow, idx);
        let vs = Simd::<f32, LANES>::from_slice(&vals[o..o + LANES]);
        acc += xs * vs;
    }
    let mut s = acc.reduce_sum();
    for o in blocks * LANES..half {
        s += vals[o] * xrow[aidx[o] as usize];
    }
    s
}

/// Scalar variant over a TRANSPOSED activation: element (i, col) of X
/// lives at `xt[col * p + i]`.
fn spmm_col_dot(wc: &Compressed24, j: usize, half: usize, xt: &[f32], p: usize,
                i: usize) -> f32 {
    let vals = &wc.values[j * half..(j + 1) * half];
    let aidx = &wc.abs_indices[j * half..(j + 1) * half];
    let mut s = 0f32;
    for h in 0..half {
        s += vals[h] * xt[aidx[h] as usize * p + i];
    }
    s
}

/// C = G Wc, everything COLUMN-major: `gt` = G^T (r, p), `ct` = C^T
/// (q, p). The compressed index selects a row of C^T; each kept value
/// contributes one contiguous AXPY along the token dimension.
pub fn spmm_nn_cm_into(gt: &Tensor, wc: &Compressed24, ct: &mut Tensor) {
    let (r, p) = gt.dims2();
    debug_assert_eq!(r, wc.rows);
    let q = wc.cols;
    let half = q / 2;
    ct.data.fill(0.0);
    for k in 0..r {
        let grow = &gt.data[k * p..(k + 1) * p];
        let vals = &wc.values[k * half..(k + 1) * half];
        let aidx = &wc.abs_indices[k * half..(k + 1) * half];
        for h in 0..half {
            let v = vals[h];
            if v == 0.0 {
                continue;
            }
            let cq = aidx[h] as usize;
            axpy(v, grow, &mut ct.data[cq * p..(cq + 1) * p]);
        }
    }
}

/// C = Gc^T X with X given COLUMN-major (`xt` = X^T, (q, p)); C (r, q)
/// row-major. Gather-dot form: each output element reads its p/2 kept
/// X values from one X^T row.
pub fn spmm_tn_cm_into(gc: &Compressed24, xt: &Tensor, c: &mut Tensor) {
    let (q, p) = xt.dims2();
    debug_assert_eq!(p, gc.cols);
    let r = gc.rows;
    let half = p / 2;
    for j in 0..r {
        let vals = &gc.values[j * half..(j + 1) * half];
        let aidx = &gc.abs_indices[j * half..(j + 1) * half];
        for k in 0..q {
            let xrow = &xt.data[k * p..(k + 1) * p];
            let mut s = 0f32;
            for h in 0..half {
                s += vals[h] * xrow[aidx[h] as usize];
            }
            c.data[j * q + k] = s;
        }
    }
}

/// C = Gc^T X with Gc 2:4-compressed along p. Gc: (r,p), X: (p,q) ->
/// C: (r,q). p/2 contiguous AXPYs per output row instead of p.
pub fn spmm_tn_into(gc: &Compressed24, x: &Tensor, c: &mut Tensor) {
    let (_, q) = x.dims2();
    let r = gc.rows;
    let half = gc.cols / 2;
    c.data.fill(0.0);
    for j in 0..r {
        let vals = &gc.values[j * half..(j + 1) * half];
        let aidx = &gc.abs_indices[j * half..(j + 1) * half];
        let crow = &mut c.data[j * q..(j + 1) * q];
        for h in 0..half {
            let v = vals[h];
            if v == 0.0 {
                continue;
            }
            let row = aidx[h] as usize;
            let xrow = &x.data[row * q..(row + 1) * q];
            axpy(v, xrow, crow);
        }
    }
}
