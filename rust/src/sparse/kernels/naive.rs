//! Reference kernels — the seed's single-threaded implementations.
//!
//! Kept verbatim (modulo the shared SIMD `dot`/`axpy` primitives) as the
//! differential-test oracle for the tiled backend and as the dispatch
//! target for problems too small to amortize tiling/threading overhead.
//! Loop orders make the innermost loop a contiguous dot or AXPY; the
//! spMM inner loops exploit the 2:4 group structure (q/2 MACs per output
//! element instead of q — the sparse-tensor-core arithmetic the paper's
//! speedups come from).

use std::simd::prelude::*;

use crate::sparse::gemm::{axpy, dot};
use crate::sparse::spmm::Compressed24;
use crate::tensor::Tensor;

/// SIMD lane width for the gather kernel (AVX2: 8 x f32).
const LANES: usize = 8;

/// C = A B^T. A: (p,q), B: (r,q) row-major -> C: (p,r).
pub fn gemm_nt_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, q) = a.dims2();
    let (r, _) = b.dims2();
    for i in 0..p {
        let arow = &a.data[i * q..(i + 1) * q];
        let crow = &mut c.data[i * r..(i + 1) * r];
        for j in 0..r {
            let brow = &b.data[j * q..(j + 1) * q];
            crow[j] = dot(arow, brow);
        }
    }
}

/// C = A B. A: (p,r), B: (r,q) row-major -> C: (p,q).
pub fn gemm_nn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, r) = a.dims2();
    let (_, q) = b.dims2();
    c.data.fill(0.0);
    for i in 0..p {
        let crow = &mut c.data[i * q..(i + 1) * q];
        for k in 0..r {
            let aik = a.data[i * r + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b.data[k * q..(k + 1) * q];
            axpy(aik, brow, crow);
        }
    }
}

/// C = A^T B. A: (p,r), B: (p,q) row-major -> C: (r,q).
pub fn gemm_tn_into(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (p, r) = a.dims2();
    let (_, q) = b.dims2();
    c.data.fill(0.0);
    for i in 0..p {
        let brow = &b.data[i * q..(i + 1) * q];
        for k in 0..r {
            let aik = a.data[i * r + k];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[k * q..(k + 1) * q];
            axpy(aik, brow, crow);
        }
    }
}

/// C = X Wc^T, Wc row-wise 2:4 compressed. X: (p,q), Wc: (r,q) -> (p,r).
/// q/2 MACs per output element via an 8-lane gather+FMA.
pub fn spmm_nt_into(x: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (p, q) = x.dims2();
    let r = wc.rows;
    let half = q / 2;
    let blocks = half / LANES;
    for i in 0..p {
        let xrow = &x.data[i * q..(i + 1) * q];
        let crow = &mut c.data[i * r..(i + 1) * r];
        for j in 0..r {
            let vals = &wc.values[j * half..(j + 1) * half];
            let aidx = &wc.abs_indices[j * half..(j + 1) * half];
            let mut acc = Simd::<f32, LANES>::splat(0.0);
            for b in 0..blocks {
                let o = b * LANES;
                let idx: Simd<usize, LANES> =
                    Simd::<u32, LANES>::from_slice(&aidx[o..o + LANES]).cast();
                let xs = Simd::<f32, LANES>::gather_or_default(xrow, idx);
                let vs = Simd::<f32, LANES>::from_slice(&vals[o..o + LANES]);
                acc += xs * vs;
            }
            let mut s = acc.reduce_sum();
            for o in blocks * LANES..half {
                s += vals[o] * xrow[aidx[o] as usize];
            }
            crow[j] = s;
        }
    }
}

/// C = G Wc (dense-equivalent W: (r,q)). G: (p,r) -> C: (p,q).
/// Scatter form: q/2 scattered MACs per (row of G, row of W).
pub fn spmm_nn_into(g: &Tensor, wc: &Compressed24, c: &mut Tensor) {
    let (p, r) = g.dims2();
    let q = wc.cols;
    let half = q / 2;
    c.data.fill(0.0);
    for i in 0..p {
        let grow = &g.data[i * r..(i + 1) * r];
        let crow = &mut c.data[i * q..(i + 1) * q];
        for k in 0..r {
            let gik = grow[k];
            if gik == 0.0 {
                continue;
            }
            let vals = &wc.values[k * half..(k + 1) * half];
            let idxs = &wc.indices[k * half..(k + 1) * half];
            for g4 in 0..q / 4 {
                let dst = &mut crow[g4 * 4..g4 * 4 + 4];
                dst[idxs[g4 * 2] as usize] += gik * vals[g4 * 2];
                dst[idxs[g4 * 2 + 1] as usize] += gik * vals[g4 * 2 + 1];
            }
        }
    }
}

/// C = Gc^T X with Gc 2:4-compressed along p. Gc: (r,p), X: (p,q) ->
/// C: (r,q). p/2 contiguous AXPYs per output row instead of p.
pub fn spmm_tn_into(gc: &Compressed24, x: &Tensor, c: &mut Tensor) {
    let (_, q) = x.dims2();
    let r = gc.rows;
    let half = gc.cols / 2;
    c.data.fill(0.0);
    for j in 0..r {
        let vals = &gc.values[j * half..(j + 1) * half];
        let aidx = &gc.abs_indices[j * half..(j + 1) * half];
        let crow = &mut c.data[j * q..(j + 1) * q];
        for h in 0..half {
            let v = vals[h];
            if v == 0.0 {
                continue;
            }
            let row = aidx[h] as usize;
            let xrow = &x.data[row * q..(row + 1) * q];
            axpy(v, xrow, crow);
        }
    }
}
