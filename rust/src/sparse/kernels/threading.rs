//! Persistent, work-stealing-free thread pool for the kernel backend.
//!
//! Design goals, in order:
//!
//! 1. **Determinism across thread counts.** Work is partitioned into
//!    contiguous, *block-aligned* row ranges (the block unit is the
//!    kernel's microkernel height). Every output row is computed by
//!    exactly one thread with exactly the same instruction sequence
//!    whatever the thread count, so results are bitwise identical for
//!    1..=N threads. This is why there is no work stealing: stealing
//!    would reassign rows dynamically, which is harmless numerically for
//!    our row-owned kernels but makes perf runs non-reproducible.
//! 2. **Zero steady-state allocation.** Workers are spawned once
//!    (lazily, on first parallel call) and parked on a condvar between
//!    jobs; a job submission allocates nothing — the closure is passed
//!    by reference through a type-erased pointer.
//! 3. **No dependencies.** `std::sync` only.
//!
//! Thread count resolution: `PALLAS_NUM_THREADS` env var, overridable at
//! runtime via [`set_num_threads`] (the `[kernels] threads` config key),
//! default `std::thread::available_parallelism()`. The pool is sized at
//! first use to cover the largest of these (at least [`MIN_POOL_WIDTH`],
//! so thread-scaling tests exercise real parallelism even on small CI
//! hosts); later `set_num_threads` calls clamp to the pool width.
//!
//! Safety: the submitting thread participates as worker 0 and does not
//! return from [`parallel_chunks`] until every worker has finished the
//! job, so the lifetime-erased closure pointer never outlives the
//! closure. Nested parallel calls from inside a job run sequentially on
//! the calling worker (guarded by a thread-local flag) instead of
//! deadlocking on the pool.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool width (worker threads incl. the caller).
pub const MAX_THREADS: usize = 64;

/// Pool is sized at least this wide so `set_num_threads(2..4)` means
/// something even on single/dual-core hosts.
const MIN_POOL_WIDTH: usize = 4;

/// Effective thread setting; 0 = not yet resolved.
static SETTING: AtomicUsize = AtomicUsize::new(0);

static POOL: OnceLock<ThreadPool> = OnceLock::new();

thread_local! {
    /// True while this thread is executing a pool job (nested parallel
    /// sections must not resubmit to the pool).
    static IN_JOB: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// RAII for the IN_JOB flag so it resets even when the job panics.
struct JobFlag;

impl JobFlag {
    fn set() -> JobFlag {
        IN_JOB.with(|g| g.set(true));
        JobFlag
    }
}

impl Drop for JobFlag {
    fn drop(&mut self) {
        IN_JOB.with(|g| g.set(false));
    }
}

/// Type-erased `&(dyn Fn(worker_idx) + Sync)` with the lifetime erased.
/// Sound because the submitter blocks until all calls complete.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync + 'static));

unsafe impl Send for TaskPtr {}

impl TaskPtr {
    fn new(f: &(dyn Fn(usize) + Sync)) -> TaskPtr {
        // Erase the closure's lifetime; see module docs for the
        // blocking contract that makes this sound.
        let ptr = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(f)
        };
        TaskPtr(ptr)
    }

    unsafe fn call(self, worker: usize) {
        unsafe { (&*self.0)(worker) }
    }
}

struct State {
    /// Incremented once per submitted job.
    epoch: u64,
    /// Workers still running the current job.
    active: usize,
    /// Worker slots participating in the current job; workers with
    /// `idx >= parts` skip it without touching `active`.
    parts: usize,
    /// A worker panicked during the current job (re-raised by the
    /// submitter after the join, so a panic never deadlocks the pool).
    poisoned: bool,
    task: Option<TaskPtr>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch.
    work_cv: Condvar,
    /// The submitter waits here for `active == 0`.
    done_cv: Condvar,
    /// Serializes whole jobs: concurrent callers (e.g. parallel test
    /// threads) take turns rather than corrupting the single job slot.
    submit: Mutex<()>,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Spawned workers (excludes the submitting thread).
    n_workers: usize,
}

impl ThreadPool {
    fn with_width(width: usize) -> ThreadPool {
        let n_workers = width.saturating_sub(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                active: 0,
                parts: 0,
                poisoned: false,
                task: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
        });
        for idx in 1..=n_workers {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("pallas-kernel-{idx}"))
                .spawn(move || worker_loop(sh, idx))
                .expect("spawning kernel pool worker");
        }
        ThreadPool { shared, n_workers }
    }

    /// Total worker slots including the submitting thread.
    pub fn width(&self) -> usize {
        self.n_workers + 1
    }

    /// Run `f(worker_idx)` on slots `0..parts`, blocking until all calls
    /// return. The caller runs slot 0; workers with `idx >= parts` skip
    /// the job without the completion-bookkeeping round trip.
    fn run(&self, f: &(dyn Fn(usize) + Sync), parts: usize) {
        let parts = parts.clamp(1, self.width());
        if self.n_workers == 0 || parts == 1 {
            let _flag = JobFlag::set();
            f(0);
            return;
        }
        let _job_turn = self.shared.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert_eq!(st.active, 0, "pool job submitted while one is running");
            st.task = Some(TaskPtr::new(f));
            st.active = parts - 1;
            st.parts = parts;
            st.poisoned = false;
            st.epoch = st.epoch.wrapping_add(1);
            self.shared.work_cv.notify_all();
        }
        // Run slot 0 on the caller, catching a panic so we still join the
        // workers first — they hold a reference to `f`, so unwinding past
        // them would leave live threads with a dangling closure.
        let caller = catch_unwind(AssertUnwindSafe(|| {
            let _flag = JobFlag::set();
            f(0);
        }));
        let poisoned;
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            poisoned = st.poisoned;
            st.poisoned = false;
            st.task = None;
        }
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if poisoned {
            panic!("kernel pool worker panicked during a parallel job");
        }
    }
}

fn worker_loop(shared: Arc<Shared>, idx: usize) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            while st.epoch == seen {
                st = shared.work_cv.wait(st).unwrap();
            }
            seen = st.epoch;
            if idx >= st.parts {
                // not a participant in this job
                continue;
            }
            st.task.expect("epoch bumped without a task")
        };
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _flag = JobFlag::set();
            unsafe { task.call(idx) }
        }));
        let mut st = shared.state.lock().unwrap();
        if result.is_err() {
            st.poisoned = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

fn env_threads() -> Option<usize> {
    std::env::var("PALLAS_NUM_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

fn default_threads() -> usize {
    env_threads()
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .min(MAX_THREADS)
}

fn pool() -> &'static ThreadPool {
    POOL.get_or_init(|| {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let width = num_threads().max(hw).max(MIN_POOL_WIDTH).min(MAX_THREADS);
        ThreadPool::with_width(width)
    })
}

/// Current effective kernel thread count.
pub fn num_threads() -> usize {
    let n = SETTING.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = default_threads();
    // Racing first calls resolve to the same value; store is idempotent.
    SETTING.store(n, Ordering::Relaxed);
    n
}

/// Override the kernel thread count (clamped to `1..=pool width` once
/// the pool exists). Returns the value that took effect.
pub fn set_num_threads(n: usize) -> usize {
    let cap = POOL.get().map(|p| p.width()).unwrap_or(MAX_THREADS);
    let n = n.clamp(1, cap);
    SETTING.store(n, Ordering::Relaxed);
    n
}

/// Balanced contiguous split of `units` work units into `parts`:
/// part `t` gets `[start, end)`.
fn split_units(units: usize, t: usize, parts: usize) -> (usize, usize) {
    let base = units / parts;
    let rem = units % parts;
    let start = t * base + t.min(rem);
    (start, start + base + usize::from(t < rem))
}

/// Run `f(row_start, row_end)` over a partition of `0..n` rows.
///
/// Ranges are aligned to `unit` rows (the microkernel height) except the
/// final range, which absorbs the `n % unit` tail — so block
/// decomposition, and therefore floating-point results, do not depend on
/// the thread count. `min_units_per_thread` keeps tiny problems
/// sequential (pool wakeup costs ~µs).
pub fn parallel_chunks(
    n: usize,
    unit: usize,
    min_units_per_thread: usize,
    f: &(dyn Fn(usize, usize) + Sync),
) {
    if n == 0 {
        return;
    }
    let unit = unit.max(1);
    let units = n.div_ceil(unit);
    let want = num_threads()
        .min(units / min_units_per_thread.max(1))
        .max(1);
    let nested = IN_JOB.with(|g| g.get());
    if want <= 1 || nested {
        f(0, n);
        return;
    }
    let p = pool();
    let parts = want.min(p.width());
    if parts <= 1 {
        f(0, n);
        return;
    }
    p.run(
        &|worker| {
            let (us, ue) = split_units(units, worker, parts);
            let start = us * unit;
            let end = (ue * unit).min(n);
            if start < end {
                f(start, end);
            }
        },
        parts,
    );
}

/// Run `f(row_start, row_end)` over a partition of `0..n` independent
/// rows (unit = 1): the row-granular convenience wrapper the attention
/// loops and the serve decode path use. `min_rows_per_thread` keeps tiny
/// problems sequential, like [`parallel_chunks`].
pub fn parallel_rows(
    n: usize,
    min_rows_per_thread: usize,
    f: &(dyn Fn(usize, usize) + Sync),
) {
    parallel_chunks(n, 1, min_rows_per_thread, f)
}

/// Shareable `*mut f32` for handing disjoint output ranges to workers.
/// Callers must guarantee ranges do not overlap across threads.
pub(crate) struct MutPtr {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for MutPtr {}
unsafe impl Sync for MutPtr {}

impl MutPtr {
    pub(crate) fn new(s: &mut [f32]) -> MutPtr {
        MutPtr { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// # Safety
    /// `[start, end)` must be in bounds and disjoint from every range
    /// handed to any other live thread.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn range(&self, start: usize, end: usize) -> &mut [f32] {
        debug_assert!(start <= end && end <= self.len);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_rows_once() {
        let n = 103;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 4, 1, &|s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn block_alignment_independent_of_threads() {
        // All non-final range starts must be multiples of the unit.
        for unit in [1usize, 4, 8] {
            let starts = Mutex::new(Vec::new());
            parallel_chunks(57, unit, 1, &|s, _e| {
                starts.lock().unwrap().push(s);
            });
            for s in starts.into_inner().unwrap() {
                assert_eq!(s % unit, 0, "unit {unit}");
            }
        }
    }

    #[test]
    fn nested_parallel_runs_sequentially() {
        let total = AtomicU64::new(0);
        parallel_chunks(8, 1, 1, &|s, e| {
            // nested call must not deadlock
            parallel_chunks(4, 1, 1, &|s2, e2| {
                total.fetch_add(((e - s) * (e2 - s2)) as u64, Ordering::Relaxed);
            });
        });
        assert!(total.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn split_units_is_balanced_and_complete() {
        for units in [1usize, 5, 16, 97] {
            for parts in [1usize, 2, 3, 8] {
                let mut next = 0;
                for t in 0..parts {
                    let (s, e) = split_units(units, t, parts);
                    assert_eq!(s, next);
                    assert!(e >= s);
                    next = e;
                }
                assert_eq!(next, units);
            }
        }
    }

    #[test]
    fn set_num_threads_clamps() {
        let prev = num_threads();
        assert_eq!(set_num_threads(1), 1);
        assert!(set_num_threads(1_000_000) <= MAX_THREADS);
        set_num_threads(prev);
    }
}
