#![feature(portable_simd)]

//! `sparse24` — 2:4 fully-sparse transformer pre-training AND serving.
//!
//! Reproduction of *Accelerating Transformer Pre-training with 2:4
//! Sparsity* (Hu et al., ICML 2024) as a three-layer Rust + JAX + Pallas
//! stack. This crate is Layer 3: the training coordinator that owns the
//! pre-training loop, the masked-decay optimizer, 2:4 mask state, flip-rate
//! instrumentation, the decay-factor tuner, the data pipeline, and the PJRT
//! runtime that executes the AOT-compiled (HLO-text) model step functions.
//! See DESIGN.md for the system inventory and experiment index.
//!
//! # Serving (`serve`)
//!
//! The [`serve`] subsystem turns a trained checkpoint into a batched
//! autoregressive inference engine: FFN weights are converted ONCE to
//! compressed 2:4 form (half the dense footprint) so every FFN forward
//! runs through the tiled `spmm_nt` kernels; prompts are ingested by
//! CHUNKED PREFILL (up to `prefill_chunk` tokens per step as one
//! matrix-form activation block — the shapes where 2:4 spMM amortizes);
//! per-sequence K/V caches live in preallocated slots carved from the
//! kernel scratch arena (the steady-state decode AND prefill paths
//! perform zero scratch-arena allocation, asserted by the arena's
//! checkout counters); and a continuous-batching scheduler
//! admits/prefills/retires requests at step granularity, fanning
//! per-sequence attention onto the persistent kernel thread pool.
//!
//! CLI subcommands (see `sparse24 help`):
//!
//! * `generate` — decode one prompt from a checkpoint (or a synthetic
//!   model with `--synthetic`), printing the sampled token ids;
//! * `serve-bench` — synthetic open-loop request load through the
//!   scheduler at two or more batch sizes; reports tokens/sec, per-lane
//!   decode p50/p99 latency, TTFT, prefill tokens/sec, and the
//!   batch-occupancy histogram, appends `serve_bench` and
//!   `prefill_tokens_per_s` sections to `BENCH_serve.json` (the latter
//!   diffed warn-only by `bench-diff`), and fails if the steady-state
//!   decode/prefill paths checked out a single fresh scratch-arena
//!   buffer (request-level bookkeeping like output token vectors is
//!   outside that contract).
//!
//! Both read the `[serve]` config table ([`config::ServeConfig`]):
//! `max_seqs`, `max_batch_tokens`, `prefill_chunk`, `max_new_tokens`,
//! `temperature`, `top_k`, `seed`, `bench_steps`, `arrival_per_step`,
//! `prompt_len`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod util;
