#![feature(portable_simd)]

//! `sparse24` — 2:4 fully-sparse transformer pre-training.
//!
//! Reproduction of *Accelerating Transformer Pre-training with 2:4
//! Sparsity* (Hu et al., ICML 2024) as a three-layer Rust + JAX + Pallas
//! stack. This crate is Layer 3: the training coordinator that owns the
//! pre-training loop, the masked-decay optimizer, 2:4 mask state, flip-rate
//! instrumentation, the decay-factor tuner, the data pipeline, and the PJRT
//! runtime that executes the AOT-compiled (HLO-text) model step functions.
//! See DESIGN.md for the system inventory and experiment index.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod util;
