#![feature(portable_simd)]

//! `sparse24` — 2:4 fully-sparse transformer pre-training AND serving.
//!
//! Reproduction of *Accelerating Transformer Pre-training with 2:4
//! Sparsity* (Hu et al., ICML 2024) as a three-layer Rust + JAX + Pallas
//! stack. This crate is Layer 3: the training coordinator that owns the
//! pre-training loop, the masked-decay optimizer, 2:4 mask state, flip-rate
//! instrumentation, the decay-factor tuner, the data pipeline, and the PJRT
//! runtime that executes the AOT-compiled (HLO-text) model step functions.
//! See DESIGN.md for the system inventory and experiment index.
//!
//! # Serving (`serve`)
//!
//! The [`serve`] subsystem turns a trained checkpoint into a batched
//! autoregressive inference engine: FFN weights are converted ONCE to
//! compressed 2:4 form (half the dense footprint) so every decode step's
//! FFN forward runs through the tiled `spmm_nt` kernels; per-sequence
//! K/V caches live in preallocated slots carved from the kernel scratch
//! arena (the steady-state decode path performs zero scratch-arena
//! allocation, asserted by the arena's checkout counters); and a
//! continuous-batching scheduler admits/retires requests at step
//! granularity, fanning per-sequence attention onto the persistent
//! kernel thread pool.
//!
//! CLI subcommands (see `sparse24 help`):
//!
//! * `generate` — decode one prompt from a checkpoint (or a synthetic
//!   model with `--synthetic`), printing the sampled token ids;
//! * `serve-bench` — synthetic open-loop request load through the
//!   scheduler at two or more batch sizes; reports tokens/sec, p50/p99
//!   per-token latency, and the batch-occupancy histogram, appends a
//!   `serve_bench` section to `BENCH_serve.json`, and fails if the
//!   steady-state decode path checked out a single fresh scratch-arena
//!   buffer (request-level bookkeeping like output token vectors is
//!   outside that contract).
//!
//! Both read the `[serve]` config table ([`config::ServeConfig`]):
//! `max_seqs`, `max_batch_tokens`, `max_new_tokens`, `temperature`,
//! `top_k`, `seed`, `bench_steps`, `arrival_per_step`, `prompt_len`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod util;
