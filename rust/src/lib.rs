#![feature(portable_simd)]

//! `sparse24` — 2:4 fully-sparse transformer pre-training AND serving.
//!
//! Reproduction of *Accelerating Transformer Pre-training with 2:4
//! Sparsity* (Hu et al., ICML 2024) as a three-layer Rust + JAX + Pallas
//! stack, grown into a train-and-serve system. The narrative tour lives
//! in `docs/ARCHITECTURE.md` (subsystem map, checkpoint→decode data
//! flow, and a paper-section → module index); the benchmark-record
//! schemas live in `docs/BENCH.md`. This page is the API-level map.
//!
//! Three subsystems, in dependency order:
//!
//! * **Kernel backend** ([`sparse`]) — the CPU stand-in for sparse
//!   tensor cores: a persistent thread pool with bitwise
//!   thread-count-invariant results, register-tiled `std::simd` GEMMs,
//!   compressed 2:4 spMM doing q/2 MACs per output element, the
//!   zero-allocation `Scratch` arena, and the paper's algorithmic
//!   pieces (transposable mask search, MVUE estimator, flip-rate
//!   instrumentation, gated activations).
//! * **Trainer** ([`coordinator`], with [`runtime`], [`optim`],
//!   [`data`], [`model`]) — the pre-training loop: leader/worker
//!   execution of AOT-compiled (HLO-text) step functions over PJRT,
//!   AdamW with the paper's masked decay, FST mask state and refresh,
//!   the decay-factor tuner, and self-describing checkpoints.
//! * **Serve engine** ([`serve`]) — a trained checkpoint becomes a
//!   batched autoregressive inference service: FFN weights frozen ONCE
//!   into compressed 2:4 form (every serving FFN forward is an
//!   `spmm_nt`), chunked matrix-form prefill, a **paged KV cache**
//!   (fixed-size pages, per-sequence page tables, admission by free
//!   pages against each request's peak need — the contiguous
//!   slot-per-sequence pool survives as the bitwise differential
//!   oracle), and a continuous-batching scheduler, all zero-allocation
//!   at steady state.
//!
//! Shared plumbing: [`config`] (TOML-subset parser + typed
//! `[train]`/`[sparse]`/`[kernels]`/`[serve]` tables), [`tensor`] (the
//! host tensor), [`util`] (PRNG, JSON, bench harness + the
//! `BENCH_*.json` emit/diff machinery).
//!
//! The `sparse24` CLI (`src/main.rs`) fronts everything: `train`,
//! `tune-decay`, `speedup`, `inspect`, `generate`, `serve-bench`,
//! `bench-diff`. See `sparse24 help`.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod util;
