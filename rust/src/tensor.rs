//! Minimal row-major f32 host tensor.
//!
//! The coordinator only needs 1-D/2-D dense math on the host side
//! (optimizer updates, mask computation, metrics); all heavy model compute
//! runs inside the AOT-compiled XLA executables. Keeping this type tiny
//! and alloc-predictable matters more than generality.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn normal(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rows/cols of a 2-D tensor (1-D is treated as a single row).
    pub fn dims2(&self) -> (usize, usize) {
        match self.shape.len() {
            1 => (1, self.shape[0]),
            2 => (self.shape[0], self.shape[1]),
            _ => panic!("dims2 on shape {:?}", self.shape),
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        let (_, c) = self.dims2();
        self.data[i * c + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        let (_, c) = self.dims2();
        &mut self.data[i * c + j]
    }

    /// Reshape in place, reusing storage where capacity allows. Contents
    /// are UNSPECIFIED afterwards (stale when the element count is
    /// unchanged, zero otherwise) — every `_into` kernel either fully
    /// overwrites its output or zeroes it itself; callers that
    /// accumulate must clear explicitly.
    pub fn resize_to(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        if self.shape != shape {
            self.shape.clear();
            self.shape.extend_from_slice(shape);
        }
        if self.data.len() != n {
            self.data.clear();
            self.data.resize(n, 0.0);
        }
    }

    pub fn t(&self) -> Tensor {
        let mut out = Tensor::zeros(&[0]);
        self.transpose_into(&mut out);
        out
    }

    /// Transposed copy into `out` (reshaped as needed, no allocation in
    /// the steady state).
    pub fn transpose_into(&self, out: &mut Tensor) {
        let (r, c) = self.dims2();
        out.resize_to(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|&x| x.abs() as f64).sum()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.dims2(), (2, 3));
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn transpose() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.t();
        assert_eq!(tt.shape, vec![3, 2]);
        assert_eq!(tt.at(2, 0), 3.0);
        assert_eq!(tt.at(0, 1), 4.0);
        assert_eq!(tt.t(), t);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(&[1, 4], vec![1., -2., 3., -4.]);
        assert_eq!(t.abs_sum(), 10.0);
        assert_eq!(t.sum(), -2.0);
        assert_eq!(t.sq_norm(), 30.0);
    }

    #[test]
    fn normal_init_has_roughly_right_std() {
        let mut rng = Rng::new(0);
        let t = Tensor::normal(&[100, 100], 0.02, &mut rng);
        let var = t.sq_norm() / t.len() as f64;
        assert!((var.sqrt() - 0.02).abs() < 0.002, "std={}", var.sqrt());
    }

    #[test]
    fn resize_and_transpose_into_reuse_storage() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut out = Tensor::zeros(&[3, 2]);
        let cap = out.data.capacity();
        let ptr = out.data.as_ptr();
        t.transpose_into(&mut out);
        assert_eq!(out, t.t());
        assert_eq!(out.data.capacity(), cap);
        assert_eq!(out.data.as_ptr(), ptr);
        out.resize_to(&[2, 2]);
        assert_eq!(out.len(), 4);
        assert!(out.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.5, 1.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
