#!/usr/bin/env bash
# Tier-1 verification entry point: build + full test suite + a quick
# bench smoke on 2 kernel threads (exercises the thread pool, the tiled
# backend, and the BENCH_kernels.json emitters end to end).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== bench smoke (PALLAS_NUM_THREADS=2, --quick)"
PALLAS_NUM_THREADS=2 cargo bench --bench ablation_spmm -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench fig7_ffn_block -- --quick

echo "== verify OK"
