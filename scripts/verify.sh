#!/usr/bin/env bash
# Tier-1 verification entry point: build + full test suite + a quick
# bench smoke on 2 kernel threads (exercises the thread pool, the tiled
# backend, and the BENCH_kernels.json emitters end to end), the chunked-
# prefill differential suite against the one-token oracle, a serving
# smoke on a tiny synthetic checkpoint (compressed-weight decode, KV
# cache, chunked prefill with prefill_chunk > 1, continuous batching,
# zero-allocation assertion, TTFT + prefill_tokens_per_s reporting), and
# a perf diff against the previous bench run (warn-only, >15%
# regression; covers GFLOP/s and prefill tok/s).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== chunked-prefill differential tests (vs one-token oracle)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_prefill

echo "== bench smoke (PALLAS_NUM_THREADS=2, --quick)"
PALLAS_NUM_THREADS=2 cargo bench --bench ablation_spmm -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench fig7_ffn_block -- --quick

echo "== serve smoke (synthetic checkpoint, 64 steps, chunked prefill, 2 threads)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve-bench --synthetic --quick \
  --steps 64 --batch-sizes 2,4 --prefill-chunk 4

echo "== bench-diff (GFLOP/s + prefill tok/s vs previous run, warn-only)"
./target/release/sparse24 bench-diff || true

echo "== verify OK"
