#!/usr/bin/env bash
# Tier-1 verification entry point: build + full test suite + rustdoc
# gate (broken intra-doc links / doc warnings fail fast) + a quick bench
# smoke on 2 kernel threads (exercises the thread pool, the tiled
# backend, and the BENCH_kernels.json emitters end to end — including
# the fused column-major Table-12 epilogue bench), the kernel
# differential suite (row-major AND _cm kernels vs the naive oracle,
# zero-staging arena counters, col-major FFN pipeline vs row-major
# oracle), the chunked-prefill differential suite against the one-token
# oracle, the paged-KV differential suite against the contiguous oracle
# (bitwise logits, fragmentation liveness, zero-alloc), a serving smoke
# on a tiny synthetic checkpoint (compressed-weight decode, paged KV
# cache, chunked prefill, continuous batching, zero-allocation
# assertion, TTFT + prefill_tokens_per_s + kv_paging occupancy
# reporting), the hardened-front-end suites (wire-level socket tests +
# KV-leak-freedom churn properties), the `serve --smoke` socket smoke
# (mid-stream disconnect -> cancel, overload reject, doomed deadline,
# graceful drain, zero-leak exit on a unix socket), the deterministic
# fault-injection bench (`serve-bench --faults`, serve_faults section),
# and a perf diff against the previous bench run (warn-only, >15%
# regression; covers GFLOP/s — table12_epilogue included — prefill
# tok/s, paged-KV occupancy, and fault-storm goodput).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== chunked-prefill differential tests (vs one-token oracle)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_prefill

echo "== paged-KV differential tests (vs contiguous oracle, bitwise)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_paged

echo "== kernel differential tests (incl. _cm epilogues vs naive oracle)"
PALLAS_NUM_THREADS=2 cargo test -q --test kernels_differential

echo "== bench smoke (PALLAS_NUM_THREADS=2, --quick)"
PALLAS_NUM_THREADS=2 cargo bench --bench ablation_spmm -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench fig7_ffn_block -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench table12_epilogue -- --quick

echo "== serve smoke (synthetic checkpoint, 64 steps, paged KV, 2 threads)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve-bench --synthetic --quick \
  --steps 64 --batch-sizes 2,4 --prefill-chunk 4 --kv-page 8

echo "== front-end suites (socket server + KV-leak churn properties)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_server
PALLAS_NUM_THREADS=2 cargo test -q --test serve_faults

echo "== server smoke (unix socket: disconnect-cancel, overload, deadline, drain)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve --smoke

echo "== fault-injection bench (seeded storm, bitwise survivors, zero leaks)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve-bench --faults --synthetic \
  --quick --steps 64

echo "== bench-diff (GFLOP/s + prefill tok/s + kv occupancy + fault goodput, warn-only)"
./target/release/sparse24 bench-diff || true

echo "== verify OK"
