#!/usr/bin/env bash
# Tier-1 verification entry point: build + full test suite + rustdoc
# gate (broken intra-doc links / doc warnings fail fast) + a quick bench
# smoke on 2 kernel threads (exercises the thread pool, the tiled
# backend, and the BENCH_kernels.json emitters end to end — including
# the fused column-major Table-12 epilogue bench), the kernel
# differential suite (row-major AND _cm kernels vs the naive oracle,
# zero-staging arena counters, col-major FFN pipeline vs row-major
# oracle), the chunked-prefill differential suite against the one-token
# oracle, the paged-KV differential suite against the contiguous oracle
# (bitwise logits, fragmentation liveness, zero-alloc), a serving smoke
# on a tiny synthetic checkpoint (compressed-weight decode, paged KV
# cache, chunked prefill, continuous batching, zero-allocation
# assertion, TTFT + prefill_tokens_per_s + kv_paging occupancy
# reporting), the hardened-front-end suites (wire-level socket tests +
# KV-leak-freedom churn properties), the `serve --smoke` socket smoke
# (mid-stream disconnect -> cancel, overload reject, doomed deadline,
# graceful drain, zero-leak exit on a unix socket), the deterministic
# fault-injection bench (`serve-bench --faults`, serve_faults section),
# the speculative-decode differential suite (spec-vs-vanilla bitwise
# across draft windows, budget property, rollback accounting,
# zero-alloc under tracing) plus a spec-enabled server smoke and a
# spec-enabled serve-bench sweep (serve_spec section),
# the activation-2:4 differential + ablation suite (activation-sparse
# fwd/bwd vs masked-dense oracles, weight-mode bitwise dispatch purity,
# 1-vs-N-thread bitwise invariance, zero-steady-state-alloc, serve
# equivalence under --sparse-mode activation, pruning tie properties),
# an activation-mode FFN speedup smoke, an activation-mode server
# smoke, and the sparse-mode ablation bench (ffn_activation24 section),
# the telemetry suite (sharded-histogram oracle, Chrome-trace
# well-formedness, zero-alloc with tracing on, bitwise invariance
# across telemetry levels and thread counts), a traced serving smoke
# whose emitted trace + metrics files are validated by `sparse24
# check-trace`, a traced short training run (skipped until `make
# artifacts` exists), the telemetry-overhead bench (advisory <3% gate),
# the training fault-tolerance suite (supervised-worker bitwise
# invariance across 1/2/3 workers, kill/panic/stall storms bitwise
# equal to an undisturbed twin, kill -> corrupt-newest -> auto-resume
# bit-exact rejoin, restore-validation naming offenders, zero leaked
# worker threads) plus the `train --faults --quick` harness smoke
# (train_faults section, nonzero exit if any bitwise oracle fails),
# and a perf diff against the previous bench run (warn-only, >15%
# regression; covers GFLOP/s — table12_epilogue included — prefill
# tok/s, paged-KV occupancy, fault-storm goodput, telemetry-mode
# tokens/s, spec accept rate + per-lane throughput, and fault-recovery
# steps/s — the ffn_activation24 rows are covered by the same generic
# GFLOP/s scan).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== chunked-prefill differential tests (vs one-token oracle)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_prefill

echo "== paged-KV differential tests (vs contiguous oracle, bitwise)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_paged

echo "== kernel differential tests (incl. _cm epilogues vs naive oracle)"
PALLAS_NUM_THREADS=2 cargo test -q --test kernels_differential

echo "== activation-2:4 differential + ablation suite (vs masked-dense oracles)"
PALLAS_NUM_THREADS=2 cargo test -q --test sparse_activation

echo "== bench smoke (PALLAS_NUM_THREADS=2, --quick)"
PALLAS_NUM_THREADS=2 cargo bench --bench ablation_spmm -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench fig7_ffn_block -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench table12_epilogue -- --quick
PALLAS_NUM_THREADS=2 cargo bench --bench ffn_activation24 -- --quick

echo "== activation-mode FFN speedup smoke (dense weights, pruned activations)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 speedup --ffn --quick \
  --sparse-mode activation

echo "== serve smoke (synthetic checkpoint, 64 steps, paged KV, spec sweep, 2 threads)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve-bench --synthetic --quick \
  --steps 64 --batch-sizes 2,4 --prefill-chunk 4 --kv-page 8 --spec-k 4

echo "== front-end suites (socket server + KV-leak churn properties)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_server
PALLAS_NUM_THREADS=2 cargo test -q --test serve_faults

echo "== speculative-decode differential suite (spec vs vanilla, bitwise)"
PALLAS_NUM_THREADS=2 cargo test -q --test serve_spec

echo "== server smoke (unix socket: disconnect-cancel, overload, deadline, drain)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve --smoke

echo "== server smoke with speculation (spec_k=3, wire-visible spec gauges)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve --smoke --spec-k 3

echo "== server smoke under activation-2:4 (dense weights, per-forward pruning)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve --smoke --sparse-mode activation

echo "== fault-injection bench (seeded storm, bitwise survivors, zero leaks)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve-bench --faults --synthetic \
  --quick --steps 64

echo "== training fault-tolerance suite (supervised workers, crash-safe checkpoints)"
PALLAS_NUM_THREADS=2 cargo test -q --test train_faults

echo "== trainer fault-injection harness (seeded storm, bitwise vs twin, auto-resume)"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 train --faults --quick

echo "== telemetry suite (shard-merge oracle, trace well-formedness, bitwise invariance)"
PALLAS_NUM_THREADS=2 cargo test -q --test obs_telemetry

echo "== traced serving smoke (+ trace/metrics file validation)"
OBS_TMP="$(mktemp -d)"
trap 'rm -rf "$OBS_TMP"' EXIT
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve-bench --synthetic --quick \
  --steps 48 --batch-sizes 2 --prefill-chunk 4 --kv-page 8 \
  --trace "$OBS_TMP/serve.trace.json" --metrics "$OBS_TMP/serve.metrics.jsonl"
./target/release/sparse24 check-trace \
  --trace "$OBS_TMP/serve.trace.json" --metrics "$OBS_TMP/serve.metrics.jsonl"
PALLAS_NUM_THREADS=2 ./target/release/sparse24 serve --smoke \
  --trace "$OBS_TMP/smoke.trace.json"
./target/release/sparse24 check-trace --trace "$OBS_TMP/smoke.trace.json"

if [ -f rust/artifacts/test_tiny_manifest.json ]; then
  echo "== traced training smoke (test_tiny, 4 steps)"
  PALLAS_NUM_THREADS=2 ./target/release/sparse24 train \
    --set model.config=test_tiny --set model.artifacts_dir=rust/artifacts \
    --set train.steps=4 --set train.warmup=2 \
    --trace "$OBS_TMP/train.trace.json" --metrics "$OBS_TMP/train.metrics.jsonl"
  ./target/release/sparse24 check-trace \
    --trace "$OBS_TMP/train.trace.json" --metrics "$OBS_TMP/train.metrics.jsonl"
else
  echo "== traced training smoke SKIPPED (no rust/artifacts/test_tiny_manifest.json)"
fi

echo "== telemetry overhead bench (off vs counters vs tracing, advisory <3% gate)"
PALLAS_NUM_THREADS=2 cargo bench --bench obs_overhead -- --quick

echo "== bench-diff (GFLOP/s + prefill tok/s + kv occupancy + fault goodput + spec accept/lane tok/s + telemetry tok/s + fault-recovery steps/s, warn-only)"
./target/release/sparse24 bench-diff || true

echo "== verify OK"
