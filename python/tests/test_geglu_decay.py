"""Fused gated activations + masked decay: kernels vs oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import geglu, masked_decay, ref
from compile.kernels.geglu import swiglu


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("shape", [(2, 8), (16, 64), (64, 256), (5, 24)])
def test_geglu_matches_oracle(shape):
    z = _rand(shape, seed=shape[1])
    np.testing.assert_allclose(np.asarray(geglu(z)), np.asarray(ref.geglu(z)), atol=1e-6)


@pytest.mark.parametrize("shape", [(2, 8), (16, 64), (3, 40)])
def test_swiglu_matches_oracle(shape):
    z = _rand(shape, seed=shape[0])
    np.testing.assert_allclose(np.asarray(swiglu(z)), np.asarray(ref.swiglu(z)), atol=1e-6)


def test_geglu_matches_jax_nn_gelu():
    """tanh-approx GELU tracks jax.nn.gelu(approximate=True) exactly."""
    x = _rand((4, 16), seed=1)
    np.testing.assert_allclose(
        np.asarray(ref.gelu_tanh(x)),
        np.asarray(jax.nn.gelu(x, approximate=True)),
        atol=1e-6,
    )


def test_geglu_zero_gate_zeroes_output():
    z1 = _rand((4, 8), seed=2)
    z = jnp.concatenate([z1, jnp.zeros_like(z1)], axis=1)
    np.testing.assert_array_equal(np.asarray(geglu(z)), np.zeros((4, 8)))


def test_masked_decay_matches_oracle():
    g, w = _rand((16, 32), 3), _rand((16, 32), 4)
    m = ref.prune24_mask(w)
    for lam in (0.0, 1e-6, 2e-4, 0.1):
        np.testing.assert_allclose(
            np.asarray(masked_decay(g, w, m, lam)),
            np.asarray(ref.masked_decay(g, w, m, lam)),
            atol=1e-7,
        )


def test_masked_decay_only_touches_pruned_weights():
    """Kept (mask=1) coordinates receive the raw gradient unchanged."""
    g, w = _rand((8, 16), 5), _rand((8, 16), 6)
    m = ref.prune24_mask(w)
    out = np.asarray(masked_decay(g, w, m, 0.5))
    keep = np.asarray(m) == 1.0
    np.testing.assert_array_equal(out[keep], np.asarray(g)[keep])
    pruned = ~keep
    np.testing.assert_allclose(
        out[pruned], (np.asarray(g) + 0.5 * np.asarray(w))[pruned], atol=1e-6
    )


def test_masked_decay_zero_lambda_is_identity():
    g, w = _rand((4, 8), 7), _rand((4, 8), 8)
    m = ref.prune24_mask(w)
    np.testing.assert_array_equal(np.asarray(masked_decay(g, w, m, 0.0)), np.asarray(g))


@settings(max_examples=10, deadline=None)
@given(p=st.integers(1, 32), r=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_geglu_property_sweep(p, r, seed):
    z = _rand((p, 2 * r), seed=seed)
    np.testing.assert_allclose(np.asarray(geglu(z)), np.asarray(ref.geglu(z)), atol=1e-5)
