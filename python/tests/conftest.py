"""Shared pytest config.

The hypothesis sweeps compile one XLA executable per unique input shape;
on the CPU JIT those accumulate mmap'd code regions until LLVM hits
"Cannot allocate memory". Clearing jax's caches between modules keeps the
whole suite inside the limit.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches():
    yield
    jax.clear_caches()
