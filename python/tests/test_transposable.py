"""Transposable-mask search: Pallas kernel vs oracle, optimality, validity."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, transposable_mask

SHAPES = [(4, 4), (8, 8), (16, 32), (64, 128), (12, 20)]


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


def _blocks(m):
    r, q = m.shape
    return m.reshape(r // 4, 4, q // 4, 4).transpose(0, 2, 1, 3).reshape(-1, 4, 4)


def test_pattern_bank_has_90_unique_valid_patterns():
    pats = np.asarray(ref.transposable_patterns())
    assert pats.shape == (90, 4, 4)
    assert len({p.tobytes() for p in pats}) == 90
    np.testing.assert_array_equal(pats.sum(1), np.full((90, 4), 2))
    np.testing.assert_array_equal(pats.sum(2), np.full((90, 4), 2))


@pytest.mark.parametrize("shape", SHAPES)
def test_matches_oracle(shape):
    w = _rand(shape, seed=shape[0] + shape[1])
    np.testing.assert_array_equal(
        np.asarray(transposable_mask(w)), np.asarray(ref.transposable_mask(w))
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_transposable_validity(shape):
    """2 ones per row AND per column of every 4x4 block (paper Fig. 8)."""
    m = np.asarray(transposable_mask(_rand(shape, seed=3)))
    for b in _blocks(m):
        np.testing.assert_array_equal(b.sum(0), [2, 2, 2, 2])
        np.testing.assert_array_equal(b.sum(1), [2, 2, 2, 2])


def test_mask_and_its_transpose_are_24():
    """Eq. 5: M and M^T both satisfy row-wise 2:4."""
    w = _rand((16, 16), seed=7)
    m = np.asarray(transposable_mask(w))
    for mat in (m, m.T):
        g = mat.reshape(mat.shape[0], mat.shape[1] // 4, 4)
        np.testing.assert_array_equal(g.sum(-1), np.full(g.shape[:-1], 2.0))


def test_exhaustive_optimality_vs_brute_force():
    """argmax over the bank == brute force over all 90 patterns."""
    w = _rand((8, 8), seed=11)
    m = np.asarray(ref.transposable_mask(w))
    pats = np.asarray(ref.transposable_patterns())
    for b, mb in zip(_blocks(np.abs(np.asarray(w))), _blocks(m)):
        best = max((pats[k] * b).sum() for k in range(90))
        np.testing.assert_allclose((mb * b).sum(), best, rtol=1e-6)


def test_dominates_2approx():
    """Conv search retains >= the 2-approximation's L1 norm (paper Table 3)."""
    w = _rand((32, 32), seed=13)
    absw = np.abs(np.asarray(w))
    ours = (np.asarray(ref.transposable_mask(w)) * absw).sum()
    approx = (np.asarray(ref.transposable_mask_2approx(w)) * absw).sum()
    assert ours >= approx - 1e-5
    # and the 2-approximation guarantee holds
    assert approx >= 0.5 * ours - 1e-5


def test_2approx_is_valid_transposable():
    m = np.asarray(ref.transposable_mask_2approx(_rand((16, 24), seed=17)))
    for b in _blocks(m):
        np.testing.assert_array_equal(b.sum(0), [2, 2, 2, 2])
        np.testing.assert_array_equal(b.sum(1), [2, 2, 2, 2])


@settings(max_examples=10, deadline=None)
@given(br=st.integers(1, 8), bq=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_property_sweep(br, bq, seed):
    w = _rand((br * 4, bq * 4), seed=seed)
    m = np.asarray(transposable_mask(w))
    np.testing.assert_array_equal(m, np.asarray(ref.transposable_mask(w)))
    for b in _blocks(m):
        assert (b.sum(0) == 2).all() and (b.sum(1) == 2).all()
