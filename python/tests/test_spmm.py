"""Pallas masked-matmul (2:4-spMM stand-in) vs plain jnp contraction."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spmm import masked_matmul_nn, masked_matmul_nt


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("p,q,r", [(4, 8, 4), (8, 16, 12), (32, 64, 48), (6, 20, 10)])
def test_nt_matches_reference(p, q, r):
    x, w = _rand((p, q), seed=p), _rand((r, q), seed=q)
    # transposable masks need 4x4-aligned dims; fall back to row-wise 2:4
    m = ref.transposable_mask(w) if r % 4 == 0 and q % 4 == 0 \
        else ref.prune24_mask(w)
    np.testing.assert_allclose(
        np.asarray(masked_matmul_nt(x, w, m)), np.asarray(x @ (w * m).T), atol=1e-4
    )


@pytest.mark.parametrize("p,q,r", [(4, 8, 4), (16, 32, 24)])
def test_nn_matches_reference(p, q, r):
    g, w = _rand((p, r), seed=r), _rand((r, q), seed=p)
    m = ref.prune24_mask(w)
    np.testing.assert_allclose(
        np.asarray(masked_matmul_nn(g, w, m)), np.asarray(g @ (w * m)), atol=1e-4
    )


def test_all_ones_mask_is_dense_matmul():
    x, w = _rand((8, 16), seed=1), _rand((12, 16), seed=2)
    m = jnp.ones_like(w)
    np.testing.assert_allclose(
        np.asarray(masked_matmul_nt(x, w, m)), np.asarray(x @ w.T), atol=1e-4
    )


def test_zero_mask_zeroes_output():
    x, w = _rand((4, 8), seed=3), _rand((4, 8), seed=4)
    out = masked_matmul_nt(x, w, jnp.zeros_like(w))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 4)))


def test_sparsity_actually_applied():
    """Output must depend only on unmasked weights."""
    x, w = _rand((4, 8), seed=5), _rand((4, 8), seed=6)
    m = ref.prune24_mask(w)
    w2 = w + 100.0 * (1.0 - m)  # perturb only masked entries
    np.testing.assert_allclose(
        np.asarray(masked_matmul_nt(x, w, m)),
        np.asarray(masked_matmul_nt(x, w2, m)),
        atol=1e-4,
    )


def test_shape_mismatch_rejected():
    with pytest.raises(Exception):
        masked_matmul_nt(_rand((4, 8)), _rand((4, 12)), jnp.ones((4, 12)))


@settings(max_examples=10, deadline=None)
@given(p=st.integers(1, 16), qg=st.integers(1, 8), r=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_property_sweep(p, qg, r, seed):
    q = qg * 4
    x, w = _rand((p, q), seed=seed), _rand((r, q), seed=seed ^ 1)
    m = ref.prune24_mask(w)
    np.testing.assert_allclose(
        np.asarray(masked_matmul_nt(x, w, m)), np.asarray(x @ (w * m).T), atol=1e-3
    )
