"""Pallas prune24 kernel vs pure-jnp oracle, plus 2:4 invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import prune24, prune24_mask, ref

SHAPES = [(4, 4), (8, 16), (16, 32), (128, 64), (96, 256), (3, 8), (1, 4)]


def _rand(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(dtype))


@pytest.mark.parametrize("shape", SHAPES)
def test_matches_oracle(shape):
    w = _rand(shape, seed=shape[0] * 100 + shape[1])
    np.testing.assert_array_equal(np.asarray(prune24(w)), np.asarray(ref.prune24(w)))
    np.testing.assert_array_equal(
        np.asarray(prune24_mask(w)), np.asarray(ref.prune24_mask(w))
    )


@pytest.mark.parametrize("shape", SHAPES)
def test_24_validity(shape):
    """Every group of 4 has exactly 2 nonzeros in the mask."""
    w = _rand(shape, seed=1)
    m = np.asarray(prune24_mask(w))
    groups = m.reshape(shape[0], shape[1] // 4, 4)
    np.testing.assert_array_equal(groups.sum(-1), np.full(groups.shape[:-1], 2.0))


def test_keeps_top2_magnitudes():
    w = jnp.asarray([[1.0, -3.0, 2.0, -0.5], [0.0, 0.0, 5.0, 1.0]], jnp.float32)
    out = np.asarray(prune24(w))
    np.testing.assert_array_equal(out, [[0.0, -3.0, 2.0, 0.0], [0.0, 0.0, 5.0, 1.0]])


def test_tie_break_lower_index():
    w = jnp.asarray([[2.0, 2.0, 2.0, 2.0]], jnp.float32)
    m = np.asarray(prune24_mask(w))
    np.testing.assert_array_equal(m, [[1.0, 1.0, 0.0, 0.0]])


def test_all_zero_group():
    w = jnp.zeros((2, 8), jnp.float32)
    m = np.asarray(prune24_mask(w))
    assert (m.reshape(2, 2, 4).sum(-1) == 2).all()  # still a valid 2:4 pattern


def test_negative_dominates_positive():
    w = jnp.asarray([[-10.0, 1.0, -9.0, 2.0]], jnp.float32)
    np.testing.assert_array_equal(np.asarray(prune24(w)), [[-10.0, 0.0, -9.0, 0.0]])


def test_rejects_bad_width():
    with pytest.raises(Exception):
        prune24(jnp.zeros((4, 6), jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 33),
    groups=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_sweep(rows, groups, seed):
    """Hypothesis sweep: kernel == oracle and pruning is idempotent."""
    w = _rand((rows, groups * 4), seed=seed)
    out = np.asarray(prune24(w))
    np.testing.assert_array_equal(out, np.asarray(ref.prune24(w)))
    # idempotence: pruning a pruned matrix changes nothing
    np.testing.assert_array_equal(np.asarray(prune24(jnp.asarray(out))), out)
    # magnitude optimality per group: kept L1 >= any other 2-subset
    g = np.abs(np.asarray(w)).reshape(rows, groups, 4)
    kept = np.abs(out).reshape(rows, groups, 4).sum(-1)
    best2 = np.sort(g, axis=-1)[..., 2:].sum(-1)
    np.testing.assert_allclose(kept, best2, rtol=1e-6)
