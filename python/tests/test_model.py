"""L2 model semantics: FST forward/backward vs the paper's Eq. 2-4."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import CONFIGS
from compile.kernels import ref

CFG = CONFIGS["test_tiny"]


def _init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for s in model.param_specs(cfg):
        if s["init"] == "zeros":
            a = np.zeros(s["shape"], np.float32)
        elif s["init"] == "ones":
            a = np.ones(s["shape"], np.float32)
        else:
            std = float(s["init"].split(":")[1])
            a = rng.normal(0, std, s["shape"]).astype(np.float32)
        out.append(jnp.asarray(a))
    return out


def _masks(cfg, params, ones=False):
    specs = model.param_specs(cfg)
    ms = []
    for i, s in enumerate(specs):
        if s.get("sparse"):
            m = jnp.ones(s["shape"], jnp.float32) if ones \
                else ref.transposable_mask(params[i])
            ms.append(m)
    return ms


def _batch(cfg, seed=1, batch=2):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_ctx)), jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_ctx)), jnp.int32)
    return t, y


def test_param_specs_count_matches_param_count():
    total = sum(int(np.prod(s["shape"])) for s in model.param_specs(CFG))
    assert total == CFG.param_count()


def test_mask_specs_align_with_sparse_params():
    specs = model.param_specs(CFG)
    msk = model.mask_specs(CFG)
    sparse = [s for s in specs if s.get("sparse")]
    assert len(msk) == len(sparse) == 2 * CFG.n_layers
    for a, b in zip(sparse, msk):
        assert b["name"] == a["name"] + ".mask"
        assert tuple(b["shape"]) == tuple(a["shape"])


def test_sparse_with_ones_mask_equals_dense_loss():
    """S(W) == W when M == 1 ⇒ identical forward loss."""
    params = _init_params(CFG)
    tokens, targets = _batch(CFG)
    ones = _masks(CFG, params, ones=True)
    l_dense = model.loss_fn(params, ones, tokens, targets, CFG, "dense")
    l_sparse = model.loss_fn(params, ones, tokens, targets, CFG, "sparse", 0)
    np.testing.assert_allclose(float(l_dense), float(l_sparse), rtol=1e-6)


def test_masked_forward_differs_from_dense():
    params = _init_params(CFG)
    tokens, targets = _batch(CFG)
    masks = _masks(CFG, params)
    l_dense = model.loss_fn(params, masks, tokens, targets, CFG, "dense")
    l_sparse = model.loss_fn(params, masks, tokens, targets, CFG, "sparse", 0)
    assert abs(float(l_dense) - float(l_sparse)) > 1e-7


def test_sparse_linear_forward_oracle():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(12, 16)).astype(np.float32))
    m = ref.transposable_mask(w)
    u = jnp.asarray(rng.random(size=(12, 2)).astype(np.float32))
    out = model.sparse_linear(x, w, m, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ (w * m).T),
                               atol=1e-5)


def test_sparse_linear_bwd_eq3_eq4():
    """∇X uses the masked weight (Eq. 3); ∇W == MVUE(∇Z^T) X (Eq. 4)."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(12, 16)).astype(np.float32))
    m = ref.transposable_mask(w)
    u = jnp.asarray(rng.random(size=(12, 2)).astype(np.float32))

    def f(x, w):
        return (model.sparse_linear(x, w, m, u) ** 2).sum() * 0.5

    dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
    gz = x @ (w * m).T  # cotangent of z for this loss
    np.testing.assert_allclose(np.asarray(dx), np.asarray(gz @ (w * m)), atol=1e-4)
    gzt = ref.mvue24(gz.T, u)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(gzt @ x), atol=1e-4)


def test_ste_linear_bwd_is_exact():
    """Ablation path: ∇W == ∇Z^T X exactly (no MVUE noise)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    m = ref.transposable_mask(w)
    u = jnp.zeros((8, 1), jnp.float32)

    def f(w):
        return (model.ste_linear(x, w, m, u) ** 2).sum() * 0.5

    dw = jax.grad(f)(w)
    gz = x @ (w * m).T
    np.testing.assert_allclose(np.asarray(dw), np.asarray(gz.T @ x), atol=1e-4)


def test_ste_gradient_flows_to_pruned_weights():
    """The STE property: masked (pruned) weights still receive gradient."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    m = ref.transposable_mask(w)
    u = jnp.asarray(rng.random(size=(8, 1)).astype(np.float32))

    dw = jax.grad(lambda w: model.sparse_linear(x, w, m, u).sum())(w)
    pruned = np.asarray(m) == 0.0
    assert np.abs(np.asarray(dw)[pruned]).sum() > 0.0


def test_step_fn_grad_count_and_finiteness():
    params = _init_params(CFG)
    masks = _masks(CFG, params)
    tokens, targets = _batch(CFG)
    for mode in ("sparse", "ste", "dense"):
        out = jax.jit(model.make_step_fn(CFG, mode))(
            params, masks, tokens, targets, jnp.asarray(0, jnp.int32)
        )
        assert len(out) == 1 + len(params)
        assert np.isfinite(float(out[0]))
        for g, p in zip(out[1:], params):
            assert g.shape == p.shape
            assert np.isfinite(np.asarray(g)).all()


def test_dense_step_matches_autodiff_reference():
    """Dense mode == straight jax.grad of a dense transformer."""
    params = _init_params(CFG)
    masks = _masks(CFG, params, ones=True)
    tokens, targets = _batch(CFG)
    out = jax.jit(model.make_step_fn(CFG, "dense"))(
        params, masks, tokens, targets, jnp.asarray(0, jnp.int32)
    )
    val, grads = jax.value_and_grad(
        lambda ps: model.loss_fn(ps, masks, tokens, targets, CFG, "dense")
    )(params)
    np.testing.assert_allclose(float(out[0]), float(val), rtol=1e-6)
    for a, b in zip(out[1:], grads):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_mvue_noise_is_seed_dependent():
    params = _init_params(CFG)
    masks = _masks(CFG, params)
    tokens, targets = _batch(CFG)
    step = jax.jit(model.make_step_fn(CFG, "sparse"))
    g1 = step(params, masks, tokens, targets, jnp.asarray(1, jnp.int32))
    g2 = step(params, masks, tokens, targets, jnp.asarray(2, jnp.int32))
    # loss identical (fwd has no noise), grads differ (MVUE sampling).
    # only FFN *weight* grads are MVUE-noised (Eq. 4); everything else is
    # deterministic (Eq. 3 uses the masked weights exactly).
    np.testing.assert_allclose(float(g1[0]), float(g2[0]), rtol=1e-6)
    specs = model.param_specs(CFG)
    ffn_w1_param = next(i for i, s in enumerate(specs) if s["name"] == "h0.ffn_w1")
    assert not np.allclose(np.asarray(g1[1 + ffn_w1_param]),
                           np.asarray(g2[1 + ffn_w1_param]))
    # attention grads stay deterministic across seeds
    wqkv_param = next(i for i, s in enumerate(specs) if s["name"] == "h0.w_qkv")
    np.testing.assert_allclose(np.asarray(g1[1 + wqkv_param]),
                               np.asarray(g2[1 + wqkv_param]), atol=1e-6)


def test_eval_fn_matches_loss():
    params = _init_params(CFG)
    masks = _masks(CFG, params)
    tokens, targets = _batch(CFG)
    ev = jax.jit(model.make_eval_fn(CFG))(params, masks, tokens, targets)
    direct = model.loss_fn(params, masks, tokens, targets, CFG, "sparse", 0)
    np.testing.assert_allclose(float(ev[0]), float(direct), rtol=1e-6)


def test_swiglu_activation_variant():
    """The model supports SwiGLU FFNs (LLaMA-style) end to end."""
    import dataclasses

    cfg = dataclasses.replace(CONFIGS["test_tiny"], name="tiny_swiglu",
                              activation="swiglu")
    params = _init_params(cfg)
    masks = _masks(cfg, params)
    tokens, targets = _batch(cfg)
    out = jax.jit(model.make_step_fn(cfg, "sparse"))(
        params, masks, tokens, targets, jnp.asarray(0, jnp.int32)
    )
    assert np.isfinite(float(out[0]))
    geglu_loss = model.loss_fn(params, masks, tokens, targets,
                               CONFIGS["test_tiny"], "sparse", 0)
    # different gate -> different loss
    assert abs(float(out[0]) - float(geglu_loss)) > 1e-7
