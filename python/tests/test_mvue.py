"""MVUE 2:4 estimator: kernel vs oracle, 2:4 validity, unbiasedness."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mvue24, ref


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


def _unif(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).random(size=shape).astype(np.float32))


@pytest.mark.parametrize("shape", [(4, 8), (16, 32), (64, 64), (7, 12)])
def test_matches_oracle(shape):
    x = _rand(shape, seed=shape[1])
    u = _unif((shape[0], shape[1] // 4), seed=shape[0])
    np.testing.assert_allclose(
        np.asarray(mvue24(x, u)), np.asarray(ref.mvue24(x, u)), atol=1e-5
    )


@pytest.mark.parametrize("seed", range(5))
def test_24_validity(seed):
    """Output has <= 2 nonzeros per group of 4 — always loadable by spMM."""
    x = _rand((32, 64), seed=seed)
    u = _unif((32, 16), seed=seed + 100)
    out = np.asarray(mvue24(x, u)).reshape(32, 16, 4)
    assert ((out != 0).sum(-1) <= 2).all()


def test_unbiasedness():
    """E[mvue24(x)] == x over many uniform draws (statistical test).

    Vectorized: one vmapped call over all draws (a single XLA compile).
    """
    import jax

    x = _rand((4, 8), seed=42)
    n_draws = 4000
    rng = np.random.default_rng(7)
    us = jnp.asarray(rng.random(size=(n_draws, 4, 2)).astype(np.float32))
    outs = jax.jit(jax.vmap(lambda u: ref.mvue24(x, u)))(us)
    mean = np.asarray(outs, np.float64).mean(0)
    # standard error of the estimator at this magnitude is ~|x|/sqrt(n)
    np.testing.assert_allclose(mean, np.asarray(x), atol=0.15)


def test_exact_when_already_sparse():
    """Groups with <= 2 nonzeros pass through exactly (zero variance)."""
    x = jnp.asarray([[3.0, 0.0, -2.0, 0.0], [0.0, 0.0, 0.0, 5.0]], jnp.float32)
    for seed in range(10):
        u = _unif((2, 1), seed=seed)
        np.testing.assert_allclose(np.asarray(ref.mvue24(x, u)), np.asarray(x), atol=1e-6)


def test_all_zero_group():
    x = jnp.zeros((2, 4), jnp.float32)
    u = _unif((2, 1), seed=0)
    np.testing.assert_array_equal(np.asarray(ref.mvue24(x, u)), np.zeros((2, 4)))


def test_probs_sum_to_two():
    a = jnp.abs(_rand((16, 8, 4), seed=3))
    p = np.asarray(ref._mvue24_probs(a))
    np.testing.assert_allclose(p.sum(-1), np.full((16, 8), 2.0), atol=1e-5)
    assert (p >= 0).all() and (p <= 1 + 1e-6).all()


def test_dominant_element_always_kept():
    """p_i == 1 for an element holding >= half the group's L1 mass."""
    x = jnp.asarray([[100.0, 1.0, 1.0, 1.0]], jnp.float32)
    for seed in range(10):
        u = _unif((1, 1), seed=seed)
        out = np.asarray(ref.mvue24(x, u))
        assert out[0, 0] == pytest.approx(100.0, rel=1e-5)


@settings(max_examples=12, deadline=None)
@given(rows=st.integers(1, 16), groups=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_property_sweep(rows, groups, seed):
    x = _rand((rows, groups * 4), seed=seed)
    u = _unif((rows, groups), seed=seed ^ 0xABCD)
    out_k = np.asarray(mvue24(x, u))
    out_r = np.asarray(ref.mvue24(x, u))
    np.testing.assert_allclose(out_k, out_r, atol=1e-5)
    g = out_r.reshape(rows, groups, 4)
    assert ((g != 0).sum(-1) <= 2).all()
    # selected entries are rescaled by >= 1 (1/p >= 1)
    nz = g[g != 0]
    orig = np.asarray(x).reshape(rows, groups, 4)[g != 0]
    assert (np.abs(nz) >= np.abs(orig) - 1e-5).all()
