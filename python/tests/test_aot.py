"""AOT export path: HLO text artifacts + manifest contract."""

import json
import os

import pytest

from compile import aot, model
from compile.configs import CONFIGS


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = CONFIGS["test_tiny"]
    manifest = aot.export_config(cfg, batch=2, out_dir=out, verbose=False)
    return out, cfg, manifest


def test_all_artifacts_written(exported):
    out, _, manifest = exported
    for fname in manifest["artifacts"].values():
        path = os.path.join(out, fname)
        assert os.path.exists(path) and os.path.getsize(path) > 1000


def test_hlo_text_is_parseable_entry(exported):
    out, _, manifest = exported
    for fname in manifest["artifacts"].values():
        text = open(os.path.join(out, fname)).read()
        assert "ENTRY" in text and "ROOT" in text
        # interchange must be plain HLO: no Mosaic/Triton custom-calls
        assert "custom-call" not in text


def test_manifest_io_contract(exported):
    out, cfg, manifest = exported
    disk = json.load(open(os.path.join(out, f"{cfg.name}_manifest.json")))
    assert disk["batch"] == 2
    assert disk["outputs"]["n_grads"] == len(disk["params"])
    pspecs = model.param_specs(cfg)
    assert [p["name"] for p in disk["params"]] == [s["name"] for s in pspecs]
    sparse_names = [s["name"] for s in pspecs if s.get("sparse")]
    assert [m["name"] for m in disk["masks"]] == [n + ".mask" for n in sparse_names]


def test_parameter_arity_in_hlo(exported):
    """Each step artifact takes params + masks + tokens + targets + seed."""
    out, cfg, manifest = exported
    n_inputs = (len(model.param_specs(cfg)) + len(model.mask_specs(cfg)) + 3)
    text = open(os.path.join(out, manifest["artifacts"]["step_sparse"])).read()
    entry = text[text.index("ENTRY"):]
    n_params = entry.count("parameter(")
    assert n_params == n_inputs, f"{n_params} parameters, expected {n_inputs}"


def test_fixture_export(tmp_path):
    cfg = CONFIGS["test_tiny"]
    aot.export_config(cfg, batch=2, out_dir=str(tmp_path), verbose=False)
    aot.export_fixture(cfg, batch=2, out_dir=str(tmp_path))
    fx = json.load(open(tmp_path / "test_tiny_fixture.json"))
    assert len(fx["params"]) == len(model.param_specs(cfg))
    assert len(fx["masks"]) == len(model.mask_specs(cfg))
    for variant in ("step_sparse", "step_ste", "step_dense"):
        exp = fx["expected"][variant]
        assert exp["loss"] > 0
        assert len(exp["grad_abs_mean"]) == len(fx["params"])
    # losses agree across variants' forward (same masked fwd for sparse/ste)
    assert abs(fx["expected"]["step_sparse"]["loss"]
               - fx["expected"]["step_ste"]["loss"]) < 1e-5
