"""L2 — the paper's compute graph: a GPT-style transformer with FST FFNs.

Fully-sparse-training (FST) semantics per the paper (Eq. 2-4):

    forward:   Z  = X (W ⊙ M)^T                         (Eq. 2)
    backward:  ∇X = ∇Z (W ⊙ M)                          (Eq. 3)
               ∇W = MVUE(∇Z^T) X                        (Eq. 4 + Eq. 6)

``M`` are the *transposable* 2:4 masks — they are INPUTS to the exported
step function, computed by the Rust coordinator (L3) every ``l`` optimizer
steps with the conv-based search, exactly as the paper refreshes them
outside the autograd graph. The MVUE estimator and the fused GEGLU run as
Pallas kernels (L1) inside this graph, so the AOT artifact genuinely
contains the kernel code paths.

Only FFN weights are sparsified (the paper sparsifies FFNs; attention
stays dense). The straight-through estimator is realised by
``jax.custom_vjp``: the cotangent of the *dense* W is taken from the
sparse product, Eq. 7.

Everything here is build-time only: ``aot.py`` lowers the step functions
to HLO text once; Python never runs on the training step path.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.geglu import geglu as geglu_kernel, swiglu as swiglu_kernel
from .kernels.mvue import mvue24 as mvue24_kernel
from .kernels.spmm import masked_matmul_nn, masked_matmul_nt

# ---------------------------------------------------------------------------
# parameter / mask layout (the manifest contract with the Rust side)
# ---------------------------------------------------------------------------

PER_LAYER_PARAMS = 12


def param_specs(cfg: ModelConfig) -> list[dict]:
    """Ordered parameter list: name, shape, init spec.

    The Rust coordinator initializes and owns the parameters; this list is
    serialized into the manifest so both sides agree on ordering and init.
    Init specs: ``normal:<std>``, ``zeros``, ``ones``.
    """
    d, r, v = cfg.d_model, cfg.d_ff, cfg.vocab
    resid_std = 0.02 / (2.0 * cfg.n_layers) ** 0.5  # GPT-2 residual scaling
    specs = [
        dict(name="tok_emb", shape=(v, d), init="normal:0.02"),
        dict(name="pos_emb", shape=(cfg.n_ctx, d), init="normal:0.01"),
    ]
    for i in range(cfg.n_layers):
        p = f"h{i}."
        specs += [
            dict(name=p + "ln1_s", shape=(d,), init="ones"),
            dict(name=p + "ln1_b", shape=(d,), init="zeros"),
            dict(name=p + "w_qkv", shape=(3 * d, d), init="normal:0.02"),
            dict(name=p + "b_qkv", shape=(3 * d,), init="zeros"),
            dict(name=p + "w_o", shape=(d, d), init=f"normal:{resid_std:.6g}"),
            dict(name=p + "b_o", shape=(d,), init="zeros"),
            dict(name=p + "ln2_s", shape=(d,), init="ones"),
            dict(name=p + "ln2_b", shape=(d,), init="zeros"),
            # fused gated up-projection (U;V) and down-projection — SPARSE
            dict(name=p + "ffn_w1", shape=(2 * r, d), init="normal:0.02",
                 sparse=True),
            dict(name=p + "ffn_b1", shape=(2 * r,), init="zeros"),
            dict(name=p + "ffn_w2", shape=(d, r), init=f"normal:{resid_std:.6g}",
                 sparse=True),
            dict(name=p + "ffn_b2", shape=(d,), init="zeros"),
        ]
    specs += [
        dict(name="lnf_s", shape=(d,), init="ones"),
        dict(name="lnf_b", shape=(d,), init="zeros"),
    ]
    return specs


def mask_specs(cfg: ModelConfig) -> list[dict]:
    """Ordered mask list (one per sparse parameter), same naming."""
    return [
        dict(name=s["name"] + ".mask", shape=s["shape"])
        for s in param_specs(cfg)
        if s.get("sparse")
    ]


# ---------------------------------------------------------------------------
# FST sparse linear (Eq. 2-4) as a custom_vjp
# ---------------------------------------------------------------------------


def _sparse_linear_fwd_impl(x, w, mask, u):
    del u
    # Eq. 2 via the L1 masked-matmul kernel (the 2:4-spMM stand-in)
    return masked_matmul_nt(x, w, mask)


@jax.custom_vjp
def sparse_linear(x, w, mask, u):
    """FST linear: fwd X(W⊙M)^T; bwd per Eq. 3 (masked W) and Eq. 4 (MVUE).

    ``u``: uniforms for the MVUE sampler, shape (w.shape[0], x.shape[0]//4).
    The mask and u receive zero cotangents (they are not trained).
    """
    return _sparse_linear_fwd_impl(x, w, mask, u)


def _sparse_linear_fwd(x, w, mask, u):
    return _sparse_linear_fwd_impl(x, w, mask, u), (x, w, mask, u)


def _sparse_linear_bwd(res, gz):
    x, w, mask, u = res
    # Eq. 3: ∇X = ∇Z (W ⊙ M) — the transposable mask makes (W⊙M) itself
    # column-wise 2:4, so this GEMM also runs on sparse tensor cores.
    dx = masked_matmul_nn(gz, w, mask)
    # Eq. 4/6: ∇W = MVUE(∇Z^T) X — unbiased 2:4 estimate of the gradient.
    gzt = mvue24_kernel(gz.T, u)
    dw = gzt @ x
    # STE (Eq. 7): the cotangent flows to the DENSE weight unchanged.
    return dx, dw, jnp.zeros_like(mask), jnp.zeros_like(u)


sparse_linear.defvjp(_sparse_linear_fwd, _sparse_linear_bwd)


def ste_linear(x, w, mask, u):
    """Ablation variant: FST without MVUE (exact ∇Z^T X, plain STE)."""

    @jax.custom_vjp
    def f(x, w, mask, u):
        return _sparse_linear_fwd_impl(x, w, mask, u)

    def fwd(x, w, mask, u):
        return _sparse_linear_fwd_impl(x, w, mask, u), (x, w, mask, u)

    def bwd(res, gz):
        x, w, mask, u = res
        return gz @ (w * mask), gz.T @ x, jnp.zeros_like(mask), jnp.zeros_like(u)

    f.defvjp(fwd, bwd)
    return f(x, w, mask, u)


# ---------------------------------------------------------------------------
# fused gated activation with analytic VJP around the Pallas kernel
# ---------------------------------------------------------------------------

_K = 0.7978845608028654  # sqrt(2/pi)
_C = 0.044715


def _gelu_tanh(x):
    return 0.5 * x * (1.0 + jnp.tanh(_K * (x + _C * x**3)))


def _gelu_tanh_grad(x):
    t = jnp.tanh(_K * (x + _C * x**3))
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * _K * (1.0 + 3.0 * _C * x * x)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _silu_grad(x):
    s = jax.nn.sigmoid(x)
    return s * (1.0 + x * (1.0 - s))


def make_gated_act(kind: str) -> Callable:
    """GEGLU/SwiGLU with the Pallas kernel on the forward pass and an
    analytic backward (pallas_call is not auto-differentiated)."""
    kernel = geglu_kernel if kind == "geglu" else swiglu_kernel
    act, dact = (_gelu_tanh, _gelu_tanh_grad) if kind == "geglu" else (_silu, _silu_grad)

    @jax.custom_vjp
    def gated(z):
        return kernel(z)

    def fwd(z):
        return kernel(z), z

    def bwd(z, g):
        r = z.shape[-1] // 2
        z1, z2 = z[:, :r], z[:, r:]
        gz1 = dact(z1) * z2 * g
        gz2 = act(z1) * g
        return (jnp.concatenate([gz1, gz2], axis=-1),)

    gated.defvjp(fwd, bwd)
    return gated


# ---------------------------------------------------------------------------
# transformer forward
# ---------------------------------------------------------------------------


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(x, w_qkv, b_qkv, w_o, b_o, cfg: ModelConfig):
    """Dense causal multi-head attention. x: (B, n, d)."""
    b, n, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x.reshape(b * n, d) @ w_qkv.T + b_qkv  # (B*n, 3d)
    qkv = qkv.reshape(b, n, 3, h, hd).transpose(2, 0, 3, 1, 4)  # (3,B,h,n,hd)
    q, k, v = qkv[0], qkv[1], qkv[2]
    scores = jnp.einsum("bhid,bhjd->bhij", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((n, n), bool))
    scores = jnp.where(causal, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhij,bhjd->bhid", probs, v)  # (B,h,n,hd)
    out = out.transpose(0, 2, 1, 3).reshape(b * n, d)
    return (out @ w_o.T + b_o).reshape(b, n, d)


def _ffn(x2d, w1, b1, w2, b2, m1, m2, u1, u2, linear_fn, gated):
    """FST feed-forward: sparse fused up-proj, gated act, sparse down-proj."""
    z = linear_fn(x2d, w1, m1, u1) + b1          # (p, 2r)
    a = gated(z)                                  # (p, r) — Pallas fused
    return linear_fn(a, w2, m2, u2) + b2          # (p, d)


def _dense_ffn(x2d, w1, b1, w2, b2, gated):
    z = x2d @ w1.T + b1
    a = gated(z)
    return a @ w2.T + b2


def forward(params: list, masks: list, tokens, cfg: ModelConfig,
            mode: str, seed=None):
    """Logits for (B, n) int32 tokens. mode: 'sparse' | 'ste' | 'dense'."""
    b, n = tokens.shape
    d, r = cfg.d_model, cfg.d_ff
    p = b * n
    gated = make_gated_act(cfg.activation)
    linear_fn = {"sparse": sparse_linear, "ste": ste_linear, "dense": None}[mode]

    if mode != "dense":
        key = jax.random.PRNGKey(seed)

    tok_emb, pos_emb = params[0], params[1]
    x = tok_emb[tokens] + pos_emb[None, :n, :]
    for i in range(cfg.n_layers):
        base = 2 + i * PER_LAYER_PARAMS
        (ln1_s, ln1_b, w_qkv, b_qkv, w_o, b_o,
         ln2_s, ln2_b, w1, b1, w2, b2) = params[base:base + PER_LAYER_PARAMS]
        x = x + _attention(_layer_norm(x, ln1_s, ln1_b), w_qkv, b_qkv, w_o,
                           b_o, cfg)
        h = _layer_norm(x, ln2_s, ln2_b).reshape(p, d)
        if mode == "dense":
            y = _dense_ffn(h, w1, b1, w2, b2, gated)
        else:
            m1, m2 = masks[2 * i], masks[2 * i + 1]
            k1, k2 = jax.random.fold_in(key, 2 * i), jax.random.fold_in(key, 2 * i + 1)
            u1 = jax.random.uniform(k1, (2 * r, p // 4), jnp.float32)
            u2 = jax.random.uniform(k2, (d, p // 4), jnp.float32)
            y = _ffn(h, w1, b1, w2, b2, m1, m2, u1, u2, linear_fn, gated)
        x = x + y.reshape(b, n, d)
    x = _layer_norm(x, params[-2], params[-1])
    return x.reshape(p, d) @ tok_emb.T  # tied head, (p, V)


def loss_fn(params, masks, tokens, targets, cfg: ModelConfig, mode: str,
            seed=None):
    """Mean cross-entropy over all positions."""
    logits = forward(params, masks, tokens, cfg, mode, seed)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = targets.reshape(-1)
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)
    return nll.mean()


# ---------------------------------------------------------------------------
# step functions (the AOT export surface)
# ---------------------------------------------------------------------------


def make_step_fn(cfg: ModelConfig, mode: str):
    """(params, masks, tokens, targets, seed) -> (loss, *grads).

    Gradients are returned for every parameter, flattened in param order.
    The optimizer (AdamW + masked decay) lives in Rust.
    """

    def step(params, masks, tokens, targets, seed):
        val, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, masks, tokens, targets, cfg, mode, seed)
        )(params)
        return (val, *grads)

    return step


def make_eval_fn(cfg: ModelConfig):
    """(params, masks, tokens, targets) -> loss, with masks applied in fwd.

    Passing all-ones masks makes this the dense eval: S(W) == W.
    """

    def evaluate(params, masks, tokens, targets):
        # sparse fwd semantics, no grad: masked weights, no MVUE involved
        return (loss_fn(params, masks, tokens, targets, cfg, "sparse", 0),)

    return evaluate
