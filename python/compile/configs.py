"""Model-size presets for the AOT compile path.

Shapes mirror the paper's study objects scaled to this testbed (1-core CPU
PJRT): ``test_tiny``/``nano`` are the pytest / cargo-test configs, ``e2e``
is the end-to-end pre-training driver config, and the ``gpt2_*`` entries
reproduce the paper's FFN shapes for the speedup benches (the Rust CPU
substrate sweeps those exact shapes; they are not exported as full models).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """GPT-style decoder-only transformer with gated (GEGLU) FFNs.

    ``d_ff`` is the FFN inner width r: the fused up-projection W1 is
    (2r x d) (U and V concatenated, paper §5.2 step 1) and the
    down-projection W2 is (d x r). FFN weights are the 2:4-sparse ones.
    """

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int          # inner width r
    n_ctx: int         # sequence length (static in the artifact)
    activation: str = "geglu"  # "geglu" | "swiglu"

    def __post_init__(self):
        assert self.d_model % self.n_heads == 0
        assert self.d_model % 4 == 0 and self.d_ff % 4 == 0
        assert self.activation in ("geglu", "swiglu")

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, r, v = self.d_model, self.d_ff, self.vocab
        per_block = (
            2 * d            # ln1
            + 3 * d * d + 3 * d  # qkv
            + d * d + d      # attn out
            + 2 * d          # ln2
            + 2 * r * d + 2 * r  # ffn w1 (fused) + b1
            + d * r + d      # ffn w2 + b2
        )
        return v * d + self.n_ctx * d + self.n_layers * per_block + 2 * d


CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        # pytest / cargo-test scale: compiles in seconds, runs in ms
        ModelConfig("test_tiny", vocab=64, d_model=32, n_layers=1, n_heads=2,
                    d_ff=32, n_ctx=16),
        ModelConfig("test_tiny_half", vocab=64, d_model=32, n_layers=1,
                    n_heads=2, d_ff=16, n_ctx=16),
        # small-but-real: used by the trainer integration tests
        ModelConfig("nano", vocab=256, d_model=64, n_layers=2, n_heads=2,
                    d_ff=128, n_ctx=64),
        ModelConfig("nano_half", vocab=256, d_model=64, n_layers=2, n_heads=2,
                    d_ff=64, n_ctx=64),
        # end-to-end pre-training driver (EXPERIMENTS.md, Fig. 10 repro)
        ModelConfig("e2e", vocab=512, d_model=256, n_layers=4, n_heads=4,
                    d_ff=512, n_ctx=128),
        # a 'half' e2e variant: d_ff halved, the paper's Half baseline
        ModelConfig("e2e_half", vocab=512, d_model=256, n_layers=4, n_heads=4,
                    d_ff=256, n_ctx=128),
        # larger optional config for longer runs
        ModelConfig("small", vocab=1024, d_model=384, n_layers=6, n_heads=6,
                    d_ff=768, n_ctx=256),
        ModelConfig("small_half", vocab=1024, d_model=384, n_layers=6, n_heads=6,
                    d_ff=384, n_ctx=256),
    ]
}

# The paper's GEMM sweep shapes (Table 3 / Fig. 7) used by the Rust benches;
# recorded here so the python and rust sides agree on the workload.
PAPER_FFN_SHAPES = [
    # (d_model, d_ff) pairs from Table 3's weight shapes
    (768, 3072),
    (1024, 4096),
    (1280, 5120),
    (1600, 6400),
    (2048, 8192),
]
