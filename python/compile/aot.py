"""AOT export: lower the L2 step functions to HLO text + manifest.json.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the runtime's XLA
(xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly.

Run once at build time (``make artifacts``); the Rust binary is then
self-contained. Usage:

    python -m compile.aot --config nano --batch 8 --out-dir ../artifacts

Artifacts per config:
    <cfg>_step_sparse.hlo.txt   FST step: masked fwd, MVUE bwd (Eq. 2-4)
    <cfg>_step_ste.hlo.txt      ablation: FST without MVUE (plain STE bwd)
    <cfg>_step_dense.hlo.txt    dense step (also used for dense fine-tune)
    <cfg>_eval.hlo.txt          loss-only eval (masks applied in fwd)
    <cfg>_manifest.json         parameter/mask/IO contract for the Rust side
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig

VARIANTS = ("sparse", "ste", "dense")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract_inputs(cfg: ModelConfig, batch: int):
    f32, i32 = jnp.float32, jnp.int32
    params = [jax.ShapeDtypeStruct(s["shape"], f32) for s in model.param_specs(cfg)]
    masks = [jax.ShapeDtypeStruct(s["shape"], f32) for s in model.mask_specs(cfg)]
    tokens = jax.ShapeDtypeStruct((batch, cfg.n_ctx), i32)
    targets = jax.ShapeDtypeStruct((batch, cfg.n_ctx), i32)
    seed = jax.ShapeDtypeStruct((), i32)
    return params, masks, tokens, targets, seed


def export_config(cfg: ModelConfig, batch: int, out_dir: str,
                  variants=VARIANTS, verbose: bool = True) -> dict:
    """Lower all step variants + eval for one config; return manifest dict."""
    params, masks, tokens, targets, seed = _abstract_inputs(cfg, batch)
    files = {}
    for variant in variants:
        fn = model.make_step_fn(cfg, variant)
        lowered = jax.jit(fn, keep_unused=True).lower(params, masks, tokens, targets, seed)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}_step_{variant}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[f"step_{variant}"] = fname
        if verbose:
            print(f"  wrote {fname} ({len(text) // 1024} KiB)")

    ev = model.make_eval_fn(cfg)
    lowered = jax.jit(ev, keep_unused=True).lower(params, masks, tokens, targets)
    fname = f"{cfg.name}_eval.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    files["eval"] = fname
    if verbose:
        print(f"  wrote {fname}")

    pspecs = model.param_specs(cfg)
    manifest = {
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "n_ctx": cfg.n_ctx,
            "activation": cfg.activation,
            "param_count": cfg.param_count(),
        },
        "batch": batch,
        # flattened positional input order of every step artifact:
        # params..., masks..., tokens, targets, seed (eval omits seed)
        "params": [
            {
                "name": s["name"],
                "shape": list(s["shape"]),
                "init": s["init"],
                "sparse": bool(s.get("sparse", False)),
            }
            for s in pspecs
        ],
        "masks": [
            {"name": s["name"], "shape": list(s["shape"])}
            for s in model.mask_specs(cfg)
        ],
        "artifacts": files,
        # step outputs: tuple (loss, grad per param in param order)
        "outputs": {"loss_index": 0, "n_grads": len(pspecs)},
    }
    mpath = os.path.join(out_dir, f"{cfg.name}_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"  wrote {os.path.basename(mpath)}")
    return manifest


def export_fixture(cfg: ModelConfig, batch: int, out_dir: str,
                   seed: int = 42) -> None:
    """Golden-value fixture for the Rust runtime integration test.

    Deterministic params/masks/batch + the loss and per-grad summaries
    computed by executing the same step functions under jax. The Rust side
    loads the corresponding HLO artifact, feeds the identical inputs, and
    must agree within float tolerance — proving the python-exec and
    rust-exec paths run the same program.
    """
    import numpy as np

    from .kernels import ref

    rng = np.random.default_rng(seed)
    params = []
    for s in model.param_specs(cfg):
        if s["init"] == "zeros":
            a = np.zeros(s["shape"], np.float32)
        elif s["init"] == "ones":
            a = np.ones(s["shape"], np.float32)
        else:
            std = float(s["init"].split(":")[1])
            a = rng.normal(0.0, std, s["shape"]).astype(np.float32)
        params.append(jnp.asarray(a))
    masks = [
        ref.transposable_mask(params[i])
        for i, s in enumerate(model.param_specs(cfg))
        if s.get("sparse")
    ]
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_ctx)),
                         jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (batch, cfg.n_ctx)),
                          jnp.int32)
    step_seed = jnp.asarray(7, jnp.int32)

    fixture = {
        "config": cfg.name,
        "batch": batch,
        "step_seed": 7,
        "params": [np.asarray(p).reshape(-1).tolist() for p in params],
        "masks": [np.asarray(m).reshape(-1).tolist() for m in masks],
        "tokens": np.asarray(tokens).reshape(-1).tolist(),
        "targets": np.asarray(targets).reshape(-1).tolist(),
        "expected": {},
    }
    for variant in VARIANTS:
        out = jax.jit(model.make_step_fn(cfg, variant))(
            params, masks, tokens, targets, step_seed
        )
        loss = float(out[0])
        grads = out[1:]
        fixture["expected"][f"step_{variant}"] = {
            "loss": loss,
            "grad_abs_mean": [float(jnp.abs(g).mean()) for g in grads],
            "grad_sum": [float(g.sum()) for g in grads],
        }
    ev = jax.jit(model.make_eval_fn(cfg))(params, masks, tokens, targets)
    fixture["expected"]["eval"] = {"loss": float(ev[0])}
    path = os.path.join(out_dir, f"{cfg.name}_fixture.json")
    with open(path, "w") as f:
        json.dump(fixture, f)
    print(f"  wrote {os.path.basename(path)}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", action="append", default=None,
                    help="config name(s); default: test_tiny nano e2e e2e_half")
    ap.add_argument("--batch", type=int, default=None,
                    help="microbatch size (default: per-config)")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fixture", action="store_true",
                    help="also write golden-value fixtures (test configs)")
    args = ap.parse_args()

    names = args.config or ["test_tiny", "test_tiny_half", "nano",
                            "nano_half", "e2e", "e2e_half"]
    default_batch = {"test_tiny": 2, "test_tiny_half": 2, "nano": 4,
                     "nano_half": 4, "e2e": 4, "e2e_half": 4,
                     "small": 4, "small_half": 4}
    os.makedirs(args.out_dir, exist_ok=True)
    for name in names:
        cfg = CONFIGS[name]
        batch = args.batch or default_batch.get(name, 4)
        print(f"exporting {name} (batch={batch}, "
              f"{cfg.param_count() / 1e6:.2f}M params)")
        export_config(cfg, batch, args.out_dir)
        if args.fixture and name in ("test_tiny", "nano"):
            export_fixture(cfg, batch, args.out_dir)


if __name__ == "__main__":
    main()
