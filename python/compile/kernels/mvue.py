"""Pallas kernel: MVUE 2:4 estimator for neural gradients (paper Eq. 6).

Unbiased 2-of-4 sampling with inclusion probabilities proportional to
magnitude (capped/redistributed), realized by systematic sampling — one
uniform per group, passed in as an input so the kernel itself is
deterministic and the surrounding jax program owns the PRNG. Elementwise
per group, no control flow: the capping loop is unrolled 3x (enough for
n=4, k=2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import group_block, row_block


def _probs(absa: jax.Array) -> jax.Array:
    """Capped-and-redistributed inclusion probabilities (unrolled)."""
    frozen = jnp.zeros_like(absa, dtype=jnp.bool_)
    p = jnp.zeros_like(absa)
    for _ in range(3):
        k_left = 2.0 - frozen.sum(-1, keepdims=True).astype(absa.dtype)
        rem = jnp.where(frozen, 0.0, absa)
        denom = jnp.maximum(rem.sum(-1, keepdims=True), 1e-30)
        raw = jnp.where(rem.sum(-1, keepdims=True) > 0, k_left * rem / denom, 0.0)
        p = jnp.where(frozen, 1.0, raw)
        frozen = frozen | ((~frozen) & (raw >= 1.0) & (rem > 0))
    return jnp.clip(p, 0.0, 1.0)


def _mvue_kernel(x_ref, u_ref, out_ref):
    x = x_ref[...]
    u = u_ref[...]
    m, n = x.shape
    g = x.reshape(m, n // 4, 4)
    p = _probs(jnp.abs(g))
    cum = jnp.cumsum(p, axis=-1)
    lo = cum - p
    uu = u.reshape(m, n // 4)[..., None]
    sel = ((uu >= lo) & (uu < cum)) | ((uu + 1.0 >= lo) & (uu + 1.0 < cum))
    out = jnp.where(sel, g / jnp.maximum(p, 1e-30), 0.0)
    out_ref[...] = out.reshape(m, n).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mvue24(x: jax.Array, u: jax.Array, interpret: bool = True) -> jax.Array:
    """Unbiased 2:4 sparsification of 2-D ``x`` along the last axis.

    ``u`` ~ U[0,1), shape (x.shape[0], x.shape[1]//4). Matches ref.mvue24.
    """
    if x.ndim != 2 or x.shape[1] % 4:
        raise ValueError(f"mvue24 expects 2-D /4 shape, got {x.shape}")
    m, n = x.shape
    bm, bn = row_block(m, n), group_block(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mvue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn // 4), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, u)
