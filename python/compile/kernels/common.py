"""Shared tiling helpers for the Pallas kernels.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): blocks are sized so a
tile fits comfortably in VMEM (~16 MiB/core; we budget <= 2 MiB per operand
tile) with the lane dimension a multiple of 128 where the array allows it,
and ALWAYS a multiple of 4 so 2:4 groups never straddle a tile boundary.
"""

from __future__ import annotations


def divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>=1)."""
    if n <= cap:
        return n
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def row_block(rows: int, cols: int, elem_bytes: int = 4,
              budget_bytes: int = 2 << 20) -> int:
    """Pick a row-tile height: whole rows, <= budget, divisor of ``rows``."""
    max_rows = max(1, budget_bytes // max(1, cols * elem_bytes))
    return divisor_at_most(rows, min(max_rows, 256))


def group_block(cols: int, cap: int = 512) -> int:
    """Column tile width: divisor of ``cols``, multiple of 4, <= cap."""
    if cols % 4 != 0:
        raise ValueError(f"cols {cols} not a multiple of 4")
    d = divisor_at_most(cols // 4, cap // 4)
    return d * 4
