"""Pallas kernel: masked matmul — the 2:4-spMM stand-in inside the graph.

On sparse tensor cores Z = X (W ⊙ M)^T runs from the compressed (values +
2-bit metadata) operand at 2x the dense rate. TPUs have no structured-
sparsity unit, so the numerically identical computation is expressed as a
masked dense contraction tiled for the MXU: each grid step multiplies a
(bp x bq) X-tile against a (br x bq) masked-W-tile (the mask multiply fuses
into the operand load in VMEM) and accumulates into the (bp x br) output
tile across the q grid axis. This is the kernel the L2 model's
``sparse_linear`` forward lowers to, so the AOT artifact carries the L1
code path end-to-end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import divisor_at_most


def _masked_mm_kernel(x_ref, w_ref, m_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    wm = w_ref[...] * m_ref[...]
    o_ref[...] += jax.lax.dot_general(
        x, wm,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_matmul_nt(x: jax.Array, w: jax.Array, mask: jax.Array,
                     interpret: bool = True) -> jax.Array:
    """Z = X (W ⊙ M)^T. x: (p, q), w/mask: (r, q) -> (p, r).

    Numerically identical to the 2:4-spMM of paper Eq. 2 when ``mask`` is
    a (transposable) 2:4 mask; tiled (bp, br, bq) with MXU-shaped blocks.
    """
    p, q = x.shape
    r, qw = w.shape
    if qw != q or mask.shape != w.shape:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} m{mask.shape}")
    bp = divisor_at_most(p, 128)
    br = divisor_at_most(r, 128)
    bq = divisor_at_most(q, 512)
    grid = (p // bp, r // br, q // bq)
    return pl.pallas_call(
        _masked_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, bq), lambda i, j, k: (i, k)),
            pl.BlockSpec((br, bq), lambda i, j, k: (j, k)),
            pl.BlockSpec((br, bq), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, r), x.dtype),
        interpret=interpret,
    )(x, w, mask)


def masked_matmul_nn(g: jax.Array, w: jax.Array, mask: jax.Array,
                     interpret: bool = True) -> jax.Array:
    """∇X = ∇Z (W ⊙ M). g: (p, r), w/mask: (r, q) -> (p, q).

    Eq. 3's GEMM: the transposable mask makes (W⊙M)^T itself 2:4, so
    hardware runs this sparse too. Reuses the NT kernel on transposed
    operands ((W⊙M) = ((W^T ⊙ M^T))^T).
    """
    return masked_matmul_nt(g, w.T, mask.T, interpret=interpret)
