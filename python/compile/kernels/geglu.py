"""Pallas kernel: fused gated activations — GEGLU / SwiGLU (paper §5.2).

The paper's CUDA problem: after a 2:4-spMM the fused (p x 2r) output Z is
COLUMN-major, so the natural row-traversal of GELU(Z1) ⊙ Z2 thrashes the
GPU L2 cache; their fix is column-order access. TPUs have no row/column-
major distinction at kernel level; the same insight maps to lane-contiguous
tiling with a single fused VMEM pass: each grid step reads one tile of Z1
and the matching tile of Z2 (both halves of the same array, selected purely
by BlockSpec index maps — no concatenate/split materialization) and writes
GELU(Z1)⊙Z2 once. One HBM read of each half, one HBM write, zero temporary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import group_block, row_block

_SQRT_2_OVER_PI = 0.7978845608028654


def _gelu_tanh(x):
    return 0.5 * x * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)))


def _silu(x):
    return x / (1.0 + jnp.exp(-x))


def _glu_kernel(z1_ref, z2_ref, out_ref, *, act: str):
    z1 = z1_ref[...]
    z2 = z2_ref[...]
    g = _gelu_tanh(z1) if act == "gelu" else _silu(z1)
    out_ref[...] = (g * z2).astype(z1.dtype)


def _call(z: jax.Array, act: str, interpret: bool) -> jax.Array:
    if z.ndim != 2 or z.shape[1] % 2:
        raise ValueError(f"gated activation expects (p, 2r), got {z.shape}")
    p, r2 = z.shape
    r = r2 // 2
    bm, bn = row_block(p, r), group_block(r) if r % 4 == 0 else r
    nj = r // bn
    kernel = functools.partial(_glu_kernel, act=act)
    return pl.pallas_call(
        kernel,
        grid=(p // bm, nj),
        in_specs=[
            # Z1 tile: left half of the fused matmul output
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            # Z2 tile: same array, offset by r columns (nj block steps)
            pl.BlockSpec((bm, bn), lambda i, j, nj=nj: (i, j + nj)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, r), z.dtype),
        interpret=interpret,
    )(z, z)


@functools.partial(jax.jit, static_argnames=("interpret",))
def geglu(z: jax.Array, interpret: bool = True) -> jax.Array:
    """GEGLU on the fused output: GELU(Z[:, :r]) ⊙ Z[:, r:]."""
    return _call(z, "gelu", interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def swiglu(z: jax.Array, interpret: bool = True) -> jax.Array:
    """SwiGLU on the fused output: SiLU(Z[:, :r]) ⊙ Z[:, r:]."""
    return _call(z, "silu", interpret)
