"""L1 — Pallas kernels for the paper's compute hot-spots.

All kernels run under ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); on a real TPU the same code lowers to Mosaic. Each
kernel is verified against the pure-jnp oracle of the same name in
:mod:`compile.kernels.ref` by the pytest suite.

Kernels (paper §5):
  * :func:`prune24.prune24`            — magnitude 2:4 pruning (S_w / S_wt)
  * :func:`transposable.transposable_mask` — conv-style transposable-mask
    search (Algorithm 1, 90-pattern bank)
  * :func:`mvue.mvue24`                — unbiased 2:4 gradient estimator
  * :func:`geglu.geglu`                — fused gated activation (§5.2)
  * :func:`masked_decay.masked_decay`  — masked decay on gradients (Eq. 10)
"""

from . import ref  # noqa: F401
from .prune24 import prune24, prune24_mask  # noqa: F401
from .transposable import transposable_mask  # noqa: F401
from .mvue import mvue24  # noqa: F401
from .geglu import geglu, swiglu  # noqa: F401
from .masked_decay import masked_decay  # noqa: F401
