"""Pallas kernel: row-wise magnitude 2:4 pruning (paper Eq. 2-3 S_w / S_wt).

The rank of every element inside its group of four is computed branch-free
(16 comparisons per group) instead of with a sort, so the kernel body is
pure vector work — the same trick the paper's Triton pruning kernel uses to
avoid divergent control flow, restated for the TPU VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import group_block, row_block


def rank_lt2(g: jax.Array) -> jax.Array:
    """{0,1} mask of the two largest |.| per group; ties -> lower index.

    ``g``: (..., 4) groups on the last axis. rank_i = #{j : |g_j| > |g_i|
    or (|g_j| == |g_i| and j < i)}; keep iff rank < 2. Branch-free.
    """
    a = jnp.abs(g)
    ai = a[..., :, None]  # (..., 4, 1) — element i
    aj = a[..., None, :]  # (..., 1, 4) — element j
    idx = jnp.arange(4)
    beats = (aj > ai) | ((aj == ai) & (idx[None, :] < idx[:, None]))
    rank = beats.sum(-1)
    return (rank < 2).astype(g.dtype)


def _prune24_kernel(x_ref, pruned_ref, mask_ref):
    x = x_ref[...]
    m, n = x.shape
    g = x.reshape(m, n // 4, 4)
    keep = rank_lt2(g).reshape(m, n)
    pruned_ref[...] = x * keep
    mask_ref[...] = keep


def _call(x: jax.Array, interpret: bool):
    if x.ndim != 2:
        raise ValueError(f"prune24 expects 2-D input, got {x.shape}")
    m, n = x.shape
    bm, bn = row_block(m, n), group_block(n)
    grid = (m // bm, n // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        _prune24_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def prune24(x: jax.Array, interpret: bool = True) -> jax.Array:
    """Magnitude 2:4 pruning of ``x`` along the last axis (2-D input)."""
    return _call(x, interpret)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def prune24_mask(x: jax.Array, interpret: bool = True) -> jax.Array:
    """{0,1} 2:4 mask of ``x`` (same semantics as ref.prune24_mask)."""
    return _call(x, interpret)[1]
