"""Pallas kernel: conv-style transposable 2:4 mask search (paper Alg. 1).

The paper replaces Hubara et al.'s branchy sort-and-pick with a dense
convolution over a 90-pattern bank so the search runs as straight-line SIMD
work. On TPU the natural restatement is a per-tile contraction: each VMEM
tile of |W| is reshaped to (blocks, 16) and multiplied against the (16, 90)
pattern bank — an MXU-shaped matmul — followed by an argmax and a gather
back to 4x4 blocks. BlockSpec carries the HBM->VMEM schedule that the CUDA
kernel expressed with threadblocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .common import divisor_at_most


def _search_kernel(absw_ref, pats_ref, mask_ref):
    absw = absw_ref[...]
    pats = pats_ref[...]  # (90, 16)
    m, n = absw.shape
    # (m/4, 4, n/4, 4) -> (m/4, n/4, 16) row-major 4x4 blocks
    blocks = absw.reshape(m // 4, 4, n // 4, 4).transpose(0, 2, 1, 3)
    blocks = blocks.reshape(m // 4, n // 4, 16)
    scores = jax.lax.dot_general(
        blocks, pats,
        dimension_numbers=(((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (m/4, n/4, 90)
    idx = jnp.argmax(scores, axis=-1)
    chosen = jnp.take(pats, idx.reshape(-1), axis=0)  # (B, 16)
    chosen = chosen.reshape(m // 4, n // 4, 4, 4).transpose(0, 2, 1, 3)
    mask_ref[...] = chosen.reshape(m, n).astype(absw.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def transposable_mask(w: jax.Array, interpret: bool = True) -> jax.Array:
    """Optimal transposable 2:4 mask of 2-D ``w`` (dims multiples of 4).

    Exhaustive over the 90 valid 4x4 patterns — exactly the paper's
    Algorithm 1 (conv2d with a 4x4x90 kernel, stride 4, then argmax).
    """
    if w.ndim != 2 or w.shape[0] % 4 or w.shape[1] % 4:
        raise ValueError(f"transposable_mask expects 2-D /4 shape, got {w.shape}")
    m, n = w.shape
    # tiles must be multiples of 4 in both dims so no 4x4 block straddles
    bm = 4 * divisor_at_most(m // 4, 64)   # <= 256 rows
    bn = 4 * divisor_at_most(n // 4, 128)  # <= 512 cols
    pats = ref.transposable_patterns().reshape(90, 16).astype(w.dtype)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _search_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((90, 16), lambda i, j: (0, 0)),  # bank resident
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
    )(jnp.abs(w), pats)
