"""Pallas kernel: masked decay on gradients (paper §4.2, Eq. 10).

g <- g + λ ((1 - m) ⊙ w): the regularization is added to the GRADIENT so
that Adam's 1/(sqrt(v)+eps) normalization turns it into a per-dimension
decay intensity — the paper's key fix over SR-STE's decay-on-weights.
Pure elementwise work; λ is compile-time static (it is fixed for a run).
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from .common import group_block, row_block


def _decay_kernel(g_ref, w_ref, m_ref, out_ref, *, lam: float):
    g = g_ref[...]
    w = w_ref[...]
    m = m_ref[...]
    out_ref[...] = (g + lam * (1.0 - m) * w).astype(g.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "interpret"))
def masked_decay(g: jax.Array, w: jax.Array, mask: jax.Array,
                 lam: float, interpret: bool = True) -> jax.Array:
    """Eq. 10: returns g + λ((1-mask) ⊙ w) for 2-D inputs of equal shape."""
    if not (g.shape == w.shape == mask.shape) or g.ndim != 2:
        raise ValueError(f"shape mismatch: {g.shape} {w.shape} {mask.shape}")
    m, n = g.shape
    bm = row_block(m, n)
    bn = group_block(n) if n % 4 == 0 else n
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_decay_kernel, lam=lam),
        grid=(m // bm, n // bn),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(g, w, mask)
