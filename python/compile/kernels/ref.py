"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the CORE correctness signal of the build path: each Pallas kernel
in this package is pytest-verified against the function of the same name
here, and the Rust ports in ``rust/src/sparse/`` agree bit-for-bit with
these definitions on shared inputs (see ``python/tests/test_cross_layer.py``
and ``rust/tests/integration_sparse.rs``).

Conventions (match the paper, Hu et al. ICML 2024, Appendix A.1):
  * "row-wise 2:4": every 4 consecutive elements *along the last axis*
    contain at least 2 zeros after pruning.
  * magnitude pruning keeps the 2 largest |w| of each group of 4; ties are
    broken toward the LOWER index (stable argsort of -|w|).
  * a "transposable" mask is a 4x4 binary block with exactly 2 ones per row
    AND 2 ones per column (90 such patterns exist).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# 2:4 magnitude pruning (the pruning functions S_wt / S_w of Eq. 2-3)
# ---------------------------------------------------------------------------


def prune24_mask(w: jax.Array) -> jax.Array:
    """Row-wise 2:4 mask of ``w`` (last axis length must be a multiple of 4).

    Returns a {0,1} mask of the same shape keeping the two largest-magnitude
    entries of each consecutive group of four, ties broken to lower index.
    """
    if w.shape[-1] % 4 != 0:
        raise ValueError(f"last axis {w.shape[-1]} not a multiple of 4")
    g = w.reshape(*w.shape[:-1], w.shape[-1] // 4, 4)
    # stable argsort of -|w|: descending magnitude, ties -> lower index first
    order = jnp.argsort(-jnp.abs(g), axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)  # rank of each position
    mask = (ranks < 2).astype(w.dtype)
    return mask.reshape(w.shape)


def prune24(w: jax.Array) -> jax.Array:
    """Row-wise magnitude 2:4 pruning: ``w * prune24_mask(w)``."""
    return w * prune24_mask(w)


# ---------------------------------------------------------------------------
# Transposable 2:4 masks (paper §5.1, Algorithm 1)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _transposable_patterns_np() -> np.ndarray:
    """All 4x4 binary matrices with exactly two 1s per row and per column.

    There are exactly 90 of them ("mask diversity n_t = 90" in the paper).
    Generated offline by exhaustive enumeration, like the paper's step (1).
    """
    rows = [r for r in range(16) if bin(r).count("1") == 2]  # 6 row patterns
    pats = []
    for a in rows:
        for b in rows:
            for c in rows:
                d_needed = 0
                ok = True
                for bit in range(4):
                    col = ((a >> bit) & 1) + ((b >> bit) & 1) + ((c >> bit) & 1)
                    if col > 2:
                        ok = False
                        break
                    if col == 1:
                        d_needed |= 1 << bit
                if not ok or bin(d_needed).count("1") != 2:
                    continue
                m = np.zeros((4, 4), dtype=np.float32)
                for i, r in enumerate((a, b, c, d_needed)):
                    for bit in range(4):
                        m[i, bit] = (r >> bit) & 1
                pats.append(m)
    arr = np.stack(pats)
    assert arr.shape[0] == 90, arr.shape
    return arr


def transposable_patterns() -> jax.Array:
    """(90, 4, 4) f32 pattern bank."""
    return jnp.asarray(_transposable_patterns_np())


def transposable_mask(w: jax.Array) -> jax.Array:
    """Optimal transposable 2:4 mask of ``w`` (2-D, dims multiples of 4).

    Exhaustive argmax over the 90 patterns per 4x4 block == the paper's
    conv2d formulation (Algorithm 1) with a (4,4,90) kernel, stride 4.
    Maximizes ||M ⊙ W||_1 exactly (the 2-approximation of Hubara et al.
    does not).
    """
    r, q = w.shape
    if r % 4 or q % 4:
        raise ValueError(f"shape {w.shape} not a multiple of 4x4")
    pats = transposable_patterns().reshape(90, 16)  # (90,16)
    absw = jnp.abs(w).reshape(r // 4, 4, q // 4, 4).transpose(0, 2, 1, 3)
    blocks = absw.reshape(r // 4, q // 4, 16)
    scores = jnp.einsum("ijk,pk->ijp", blocks, pats)  # (r/4, q/4, 90)
    idx = jnp.argmax(scores, axis=-1)  # ties -> lower pattern index
    chosen = pats[idx].reshape(r // 4, q // 4, 4, 4)
    mask = chosen.transpose(0, 2, 1, 3).reshape(r, q)
    return mask.astype(w.dtype)


def transposable_mask_2approx(w: jax.Array) -> jax.Array:
    """Hubara et al. (2021) 2-approximation baseline (sort & pick).

    Greedy: visit entries of each 4x4 block in decreasing |w|; keep an entry
    if its row and column each still have < 2 kept entries. The pure greedy
    pass can dead-end with < 8 kept entries (all admissible rows/columns
    exhausted); the repair pass then completes it with the best valid
    pattern containing the kept set — mirroring Hubara et al.'s fix-up
    stage. Yields a valid transposable mask with ||M⊙W||_1 >= 1/2 optimal.
    """
    r, q = w.shape
    absw = jnp.abs(w).reshape(r // 4, 4, q // 4, 4).transpose(0, 2, 1, 3)
    blocks = absw.reshape(-1, 16)  # (B,16) in row-major 4x4 order
    pats = transposable_patterns().reshape(90, 16)  # (90,16)

    def per_block(b):
        order = jnp.argsort(-b, stable=True)

        def body(state, pos):
            rows, cols, m = state
            i, j = pos // 4, pos % 4
            take = (rows[i] < 2) & (cols[j] < 2)
            rows = rows.at[i].add(jnp.where(take, 1, 0))
            cols = cols.at[j].add(jnp.where(take, 1, 0))
            m = m.at[pos].set(jnp.where(take, 1.0, 0.0))
            return (rows, cols, m), None

        init = (jnp.zeros(4, jnp.int32), jnp.zeros(4, jnp.int32), jnp.zeros(16))
        (rows, cols, m), _ = jax.lax.scan(body, init, order)
        # repair: snap to the valid pattern keeping as many greedy picks as
        # possible (overlap dominates), then by retained |w|
        big = 1.0 + 16.0 * jnp.max(b)
        scores = pats @ (b + big * m)
        return pats[jnp.argmax(scores)]

    masks = jax.vmap(per_block)(blocks).reshape(r // 4, q // 4, 4, 4)
    return masks.transpose(0, 2, 1, 3).reshape(r, q).astype(w.dtype)


# ---------------------------------------------------------------------------
# MVUE 2:4 estimator for neural gradients (paper Eq. 6; Chmiel et al. 2023)
# ---------------------------------------------------------------------------


def _mvue24_probs(a: jax.Array) -> jax.Array:
    """Inclusion probabilities for 2-of-4 sampling proportional to |a|.

    p_i = min(1, 2|a_i|/sum|a|) with iterative redistribution of the capped
    mass (<=3 rounds suffice for n=4, k=2). sum(p) == min(2, nnz).
    """
    absa = jnp.abs(a)
    frozen = jnp.zeros_like(absa, dtype=bool)

    def round_(state, _):
        frozen, _ = state
        k_left = 2.0 - frozen.sum(-1, keepdims=True).astype(absa.dtype)
        rem = jnp.where(frozen, 0.0, absa)
        denom = rem.sum(-1, keepdims=True)
        raw = jnp.where(denom > 0, k_left * rem / jnp.maximum(denom, 1e-30), 0.0)
        p = jnp.where(frozen, 1.0, raw)
        newly = (~frozen) & (raw >= 1.0) & (rem > 0)
        return (frozen | newly, p), None

    (frozen, p), _ = jax.lax.scan(
        round_, (frozen, jnp.zeros_like(absa)), None, length=4
    )
    return jnp.clip(p, 0.0, 1.0)


def mvue24(x: jax.Array, u: jax.Array) -> jax.Array:
    """Unbiased 2:4 sparsification of ``x`` along the last axis.

    ``u`` ~ U[0,1) with shape ``x.shape[:-1] + (x.shape[-1]//4,)`` — one
    uniform per group of four. Systematic (cumulative-interval) sampling
    selects exactly the entries whose cumulative-probability interval
    contains ``u + j`` (j = 0, 1), giving exact per-entry inclusion
    marginals p_i; kept entries are rescaled by 1/p_i, so E[out] == x.
    Groups with <= 2 nonzeros are passed through exactly (zero variance).
    """
    if x.shape[-1] % 4 != 0:
        raise ValueError(f"last axis {x.shape[-1]} not a multiple of 4")
    g = x.reshape(*x.shape[:-1], x.shape[-1] // 4, 4)
    p = _mvue24_probs(g)
    cum = jnp.cumsum(p, axis=-1)
    lo = cum - p
    uu = u[..., None]  # (.., G, 1)
    # entry i selected iff some integer offset u+j lies in [lo_i, lo_i + p_i)
    sel = ((uu >= lo) & (uu < cum)) | ((uu + 1.0 >= lo) & (uu + 1.0 < cum))
    out = jnp.where(sel, g / jnp.maximum(p, 1e-30), 0.0)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated activations (paper §5.2)
# ---------------------------------------------------------------------------

_SQRT_2_OVER_PI = 0.7978845608028654


def gelu_tanh(x: jax.Array) -> jax.Array:
    """tanh-approximated GELU (matches the Rust port exactly)."""
    return 0.5 * x * (1.0 + jnp.tanh(_SQRT_2_OVER_PI * (x + 0.044715 * x**3)))


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def geglu(z: jax.Array) -> jax.Array:
    """GEGLU on the fused matmul output: split last axis, GELU(Z1) ⊙ Z2."""
    z1, z2 = jnp.split(z, 2, axis=-1)
    return gelu_tanh(z1) * z2


def swiglu(z: jax.Array) -> jax.Array:
    z1, z2 = jnp.split(z, 2, axis=-1)
    return silu(z1) * z2


# ---------------------------------------------------------------------------
# Masked decay (paper §4.2, Eq. 10) and flip rate (Definition 4.1)
# ---------------------------------------------------------------------------


def masked_decay(g: jax.Array, w: jax.Array, mask: jax.Array, lam: float) -> jax.Array:
    """g + λ ((1 - m) ⊙ w): decay applied on GRADIENTS (ours, Eq. 10)."""
    return g + lam * (1.0 - mask) * w


def flip_rate(m_prev: jax.Array, m_new: jax.Array) -> jax.Array:
    """Definition 4.1: ||m_t - m_{t-1}||_1 / D."""
    return jnp.abs(m_new - m_prev).mean()
